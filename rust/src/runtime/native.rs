//! Pure-Rust compute backend (reference implementation, any shape).
//!
//! The allocating trait methods delegate to the `_into` overrides through
//! fresh buffers, so both forms are bitwise identical by construction.

use super::backend::{ComputeBackend, KernelWorkspace, MU_EPS};
use crate::linalg::gemm::{gram_mt_m_into, matmul_at_b_into_ws, matmul_into_ws};
use crate::linalg::sparse::{sp_matmul_at_b_with, sp_matmul_with, SparseMat};
use crate::linalg::Mat;

/// Native backend built on `crate::linalg`.
#[derive(Default, Clone, Copy, Debug)]
pub struct NativeBackend;

impl ComputeBackend for NativeBackend {
    fn gram(&self, f: &Mat<f64>) -> Mat<f64> {
        let mut out = Mat::zeros(0, 0);
        self.gram_into(f, &mut out, &mut KernelWorkspace::new());
        out
    }

    fn xht(&self, x: &Mat<f64>, ht: &Mat<f64>) -> Mat<f64> {
        let mut out = Mat::zeros(0, 0);
        self.xht_into(x, ht, &mut out, &mut KernelWorkspace::new());
        out
    }

    fn wtx(&self, x: &Mat<f64>, w: &Mat<f64>) -> Mat<f64> {
        let mut out = Mat::zeros(0, 0);
        self.wtx_into(x, w, &mut out, &mut KernelWorkspace::new());
        out
    }

    fn bcd_update(&self, fm: &Mat<f64>, g: &Mat<f64>, p: &Mat<f64>, lip: f64) -> Mat<f64> {
        let mut out = Mat::zeros(0, 0);
        self.bcd_update_into(fm, g, p, lip, &mut out, &mut KernelWorkspace::new());
        out
    }

    fn mu_update(&self, f: &Mat<f64>, g: &Mat<f64>, p: &Mat<f64>) -> Mat<f64> {
        let mut out = f.clone();
        self.mu_update_inplace(&mut out, g, p, &mut KernelWorkspace::new());
        out
    }

    fn gram_into(&self, f: &Mat<f64>, out: &mut Mat<f64>, _ws: &mut KernelWorkspace) {
        // gram_mt_m_into zeroes the output itself.
        out.resize_for_overwrite(f.cols(), f.cols());
        gram_mt_m_into(f, out);
    }

    fn xht_into(&self, x: &Mat<f64>, ht: &Mat<f64>, out: &mut Mat<f64>, ws: &mut KernelWorkspace) {
        // Both GEMM branches zero C before accumulating.
        out.resize_for_overwrite(x.rows(), ht.cols());
        matmul_into_ws(x, ht, out, &mut ws.gemm);
    }

    fn wtx_into(&self, x: &Mat<f64>, w: &Mat<f64>, out: &mut Mat<f64>, ws: &mut KernelWorkspace) {
        out.resize_for_overwrite(x.cols(), w.cols());
        matmul_at_b_into_ws(x, w, out, &mut ws.gemm);
    }

    fn bcd_update_into(
        &self,
        fm: &Mat<f64>,
        g: &Mat<f64>,
        p: &Mat<f64>,
        lip: f64,
        out: &mut Mat<f64>,
        ws: &mut KernelWorkspace,
    ) {
        debug_assert!(lip > 0.0);
        ws.fg.resize_for_overwrite(fm.rows(), g.cols());
        matmul_into_ws(fm, g, &mut ws.fg, &mut ws.gemm);
        // max(0, fm - (fm·g - p)/lip), fused elementwise (writes every
        // element, so the output skips the zero-fill too).
        let inv = 1.0 / lip;
        out.resize_for_overwrite(fm.rows(), g.cols());
        let (o, fms, fgs, ps) = (out.as_mut_slice(), fm.as_slice(), ws.fg.as_slice(), p.as_slice());
        for i in 0..o.len() {
            let v = fms[i] - (fgs[i] - ps[i]) * inv;
            o[i] = if v > 0.0 { v } else { 0.0 };
        }
    }

    fn mu_update_inplace(
        &self,
        f: &mut Mat<f64>,
        g: &Mat<f64>,
        p: &Mat<f64>,
        ws: &mut KernelWorkspace,
    ) {
        ws.fg.resize_for_overwrite(f.rows(), g.cols());
        matmul_into_ws(f, g, &mut ws.fg, &mut ws.gemm);
        let (o, fgs, ps) = (f.as_mut_slice(), ws.fg.as_slice(), p.as_slice());
        for i in 0..o.len() {
            o[i] *= ps[i] / (fgs[i] + MU_EPS);
        }
    }

    fn xht_sparse_into(
        &self,
        x: &SparseMat,
        ht: &Mat<f64>,
        out: &mut Mat<f64>,
        ws: &mut KernelWorkspace,
    ) {
        // The SpMM zeroes every output row itself; the kernel selection
        // (SIMD path + intra-rank threads) rides on the GEMM workspace.
        out.resize_for_overwrite(x.rows(), ht.cols());
        sp_matmul_with(x, ht, out, ws.gemm.kernel());
    }

    fn wtx_sparse_into(
        &self,
        x: &SparseMat,
        w: &Mat<f64>,
        out: &mut Mat<f64>,
        ws: &mut KernelWorkspace,
    ) {
        out.resize_for_overwrite(x.cols(), w.cols());
        sp_matmul_at_b_with(x, w, out, ws.gemm.kernel());
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{gram_mt_m, matmul, matmul_naive};
    use crate::util::rng::Rng;

    #[test]
    fn bcd_update_projects_nonneg() {
        let mut rng = Rng::new(1);
        let b = NativeBackend;
        let fm = Mat::rand_uniform(6, 3, &mut rng);
        let g = gram_mt_m(&Mat::<f64>::rand_uniform(10, 3, &mut rng));
        let p = Mat::rand_uniform(6, 3, &mut rng);
        let out = b.bcd_update(&fm, &g, &p, g.fro_norm());
        assert!(out.is_nonneg());
        assert_eq!(out.shape(), (6, 3));
    }

    #[test]
    fn bcd_update_is_projected_gradient() {
        // With lip = 1 and g = I: out = max(0, fm - fm + p) = max(0, p).
        let fm = Mat::from_vec(1, 2, vec![3.0, 5.0]);
        let g = Mat::eye(2);
        let p = Mat::from_vec(1, 2, vec![-1.0, 2.0]);
        let out = NativeBackend.bcd_update(&fm, &g, &p, 1.0);
        assert_eq!(out.as_slice(), &[0.0, 2.0]);
    }

    #[test]
    fn mu_update_fixed_point_at_exact_factorization() {
        // If F·G == P elementwise then F is (almost) unchanged.
        let mut rng = Rng::new(2);
        let f = Mat::<f64>::rand_uniform(5, 3, &mut rng);
        let g = gram_mt_m(&Mat::<f64>::rand_uniform(7, 3, &mut rng));
        let p = matmul(&f, &g);
        let out = NativeBackend.mu_update(&f, &g, &p);
        for (a, b) in out.as_slice().iter().zip(f.as_slice()) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn mu_preserves_nonnegativity_and_zeros() {
        let f = Mat::from_vec(1, 2, vec![0.0, 1.0]);
        let g = Mat::eye(2);
        let p = Mat::from_vec(1, 2, vec![5.0, 5.0]);
        let out = NativeBackend.mu_update(&f, &g, &p);
        assert_eq!(out.as_slice()[0], 0.0); // zeros stay zero under MU
        assert!(out.as_slice()[1] > 0.0);
    }

    #[test]
    fn into_variants_match_allocating_bitwise_with_reused_workspace() {
        let mut rng = Rng::new(4);
        let b = NativeBackend;
        let mut ws = KernelWorkspace::new();
        let mut out = Mat::zeros(0, 0);
        // Two different shapes through the same workspace.
        for &(rows, r, cols) in &[(40usize, 6usize, 50usize), (23, 4, 31)] {
            let x = Mat::<f64>::rand_uniform(rows, cols, &mut rng);
            let ht = Mat::<f64>::rand_uniform(cols, r, &mut rng);
            let w = Mat::<f64>::rand_uniform(rows, r, &mut rng);
            b.xht_into(&x, &ht, &mut out, &mut ws);
            assert_eq!(out.as_slice(), b.xht(&x, &ht).as_slice());
            b.wtx_into(&x, &w, &mut out, &mut ws);
            assert_eq!(out.as_slice(), b.wtx(&x, &w).as_slice());
            b.gram_into(&w, &mut out, &mut ws);
            assert_eq!(out.as_slice(), b.gram(&w).as_slice());
            let g = b.gram(&ht);
            let p = b.xht(&x, &ht);
            b.bcd_update_into(&w, &g, &p, g.fro_norm(), &mut out, &mut ws);
            assert_eq!(out.as_slice(), b.bcd_update(&w, &g, &p, g.fro_norm()).as_slice());
            let mut f = w.clone();
            b.mu_update_inplace(&mut f, &g, &p, &mut ws);
            assert_eq!(f.as_slice(), b.mu_update(&w, &g, &p).as_slice());
        }
    }

    #[test]
    fn sparse_into_variants_match_allocating_and_dense_bitwise() {
        let mut rng = Rng::new(5);
        let b = NativeBackend;
        let mut ws = KernelWorkspace::new();
        let mut out = Mat::zeros(0, 0);
        // A non-negative X with exact zeros: the sparse kernels must match
        // both their allocating defaults and the dense kernels bitwise.
        let xd = Mat::<f64>::from_fn(30, 22, |i, j| {
            if (i * 31 + j * 7) % 5 == 0 {
                ((i + 1) * (j + 2) % 13) as f64 * 0.25
            } else {
                0.0
            }
        });
        let xs = SparseMat::from_dense(&xd);
        let ht = Mat::<f64>::rand_uniform(22, 4, &mut rng);
        let w = Mat::<f64>::rand_uniform(30, 4, &mut rng);
        b.xht_sparse_into(&xs, &ht, &mut out, &mut ws);
        assert_eq!(out.as_slice(), b.xht_sparse(&xs, &ht).as_slice());
        assert_eq!(out.as_slice(), matmul_naive(&xd, &ht).as_slice());
        b.wtx_sparse_into(&xs, &w, &mut out, &mut ws);
        assert_eq!(out.as_slice(), b.wtx_sparse(&xs, &w).as_slice());
        assert_eq!(out.as_slice(), matmul_naive(&xd.transpose(), &w).as_slice());
        // And the dense kernels agree to roundoff (they may take the FMA
        // fallback at this size).
        let dense = b.xht(&xd, &ht);
        for (a, c) in out.as_slice().iter().zip(b.wtx(&xd, &w).as_slice()) {
            assert!((a - c).abs() <= 1e-12 * (1.0 + a.abs()));
        }
        for (a, c) in b.xht_sparse(&xs, &ht).as_slice().iter().zip(dense.as_slice()) {
            assert!((a - c).abs() <= 1e-12 * (1.0 + a.abs()));
        }
    }
}
