//! Pure-Rust compute backend (reference implementation, any shape).

use super::backend::{ComputeBackend, MU_EPS};
use crate::linalg::gemm::{gram_mt_m, matmul, matmul_at_b, matmul_into};
use crate::linalg::Mat;

/// Native backend built on `crate::linalg`.
#[derive(Default, Clone, Copy, Debug)]
pub struct NativeBackend;

impl ComputeBackend for NativeBackend {
    fn gram(&self, f: &Mat<f64>) -> Mat<f64> {
        gram_mt_m(f)
    }

    fn xht(&self, x: &Mat<f64>, ht: &Mat<f64>) -> Mat<f64> {
        matmul(x, ht)
    }

    fn wtx(&self, x: &Mat<f64>, w: &Mat<f64>) -> Mat<f64> {
        matmul_at_b(x, w)
    }

    fn bcd_update(&self, fm: &Mat<f64>, g: &Mat<f64>, p: &Mat<f64>, lip: f64) -> Mat<f64> {
        debug_assert!(lip > 0.0);
        let mut fg = Mat::zeros(fm.rows(), g.cols());
        matmul_into(fm, g, &mut fg);
        // max(0, fm - (fm·g - p)/lip), fused elementwise.
        let inv = 1.0 / lip;
        let mut out = fm.clone();
        let (o, fgs, ps) = (out.as_mut_slice(), fg.as_slice(), p.as_slice());
        for i in 0..o.len() {
            let v = o[i] - (fgs[i] - ps[i]) * inv;
            o[i] = if v > 0.0 { v } else { 0.0 };
        }
        out
    }

    fn mu_update(&self, f: &Mat<f64>, g: &Mat<f64>, p: &Mat<f64>) -> Mat<f64> {
        let mut fg = Mat::zeros(f.rows(), g.cols());
        matmul_into(f, g, &mut fg);
        let mut out = f.clone();
        let (o, fgs, ps) = (out.as_mut_slice(), fg.as_slice(), p.as_slice());
        for i in 0..o.len() {
            o[i] *= ps[i] / (fgs[i] + MU_EPS);
        }
        out
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bcd_update_projects_nonneg() {
        let mut rng = Rng::new(1);
        let b = NativeBackend;
        let fm = Mat::rand_uniform(6, 3, &mut rng);
        let g = gram_mt_m(&Mat::<f64>::rand_uniform(10, 3, &mut rng));
        let p = Mat::rand_uniform(6, 3, &mut rng);
        let out = b.bcd_update(&fm, &g, &p, g.fro_norm());
        assert!(out.is_nonneg());
        assert_eq!(out.shape(), (6, 3));
    }

    #[test]
    fn bcd_update_is_projected_gradient() {
        // With lip = 1 and g = I: out = max(0, fm - fm + p) = max(0, p).
        let fm = Mat::from_vec(1, 2, vec![3.0, 5.0]);
        let g = Mat::eye(2);
        let p = Mat::from_vec(1, 2, vec![-1.0, 2.0]);
        let out = NativeBackend.bcd_update(&fm, &g, &p, 1.0);
        assert_eq!(out.as_slice(), &[0.0, 2.0]);
    }

    #[test]
    fn mu_update_fixed_point_at_exact_factorization() {
        // If F·G == P elementwise then F is (almost) unchanged.
        let mut rng = Rng::new(2);
        let f = Mat::<f64>::rand_uniform(5, 3, &mut rng);
        let g = gram_mt_m(&Mat::<f64>::rand_uniform(7, 3, &mut rng));
        let p = matmul(&f, &g);
        let out = NativeBackend.mu_update(&f, &g, &p);
        for (a, b) in out.as_slice().iter().zip(f.as_slice()) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn mu_preserves_nonnegativity_and_zeros() {
        let f = Mat::from_vec(1, 2, vec![0.0, 1.0]);
        let g = Mat::eye(2);
        let p = Mat::from_vec(1, 2, vec![5.0, 5.0]);
        let out = NativeBackend.mu_update(&f, &g, &p);
        assert_eq!(out.as_slice()[0], 0.0); // zeros stay zero under MU
        assert!(out.as_slice()[1] > 0.0);
    }
}
