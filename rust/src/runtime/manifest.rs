//! Artifact manifest (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`).

use crate::error::{DnttError, Result};
use crate::util::json::Json;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One AOT-compiled op instance.
#[derive(Clone, Debug)]
pub struct OpArtifact {
    pub key: String,
    pub op: String,
    pub dims: Vec<usize>,
    pub path: PathBuf,
    pub outputs: usize,
}

/// Parsed manifest: op-key → artifact.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    entries: HashMap<String, OpArtifact>,
}

impl Manifest {
    /// Load from `<dir>/manifest.json`. Missing manifest is not an error —
    /// it yields an empty manifest (pure native fallback).
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        if !path.exists() {
            return Ok(Manifest::default());
        }
        let text = std::fs::read_to_string(&path)?;
        let json = Json::parse(&text)?;
        let mut entries = HashMap::new();
        for op in json.get("ops").as_arr().unwrap_or(&[]) {
            let key = op
                .get("key")
                .as_str()
                .ok_or_else(|| DnttError::Artifact("manifest op missing key".into()))?
                .to_string();
            let file = op
                .get("file")
                .as_str()
                .ok_or_else(|| DnttError::Artifact(format!("op {key}: missing file")))?;
            let dims = op
                .get("dims")
                .as_arr()
                .map(|a| a.iter().filter_map(|d| d.as_usize()).collect())
                .unwrap_or_default();
            let artifact = OpArtifact {
                key: key.clone(),
                op: op.get("op").as_str().unwrap_or("").to_string(),
                dims,
                path: dir.join(file),
                outputs: op.get("outputs").as_usize().unwrap_or(1),
            };
            if !artifact.path.exists() {
                return Err(DnttError::Artifact(format!(
                    "manifest references missing file {:?}",
                    artifact.path
                )));
            }
            entries.insert(key, artifact);
        }
        Ok(Manifest { entries })
    }

    pub fn get(&self, key: &str) -> Option<&OpArtifact> {
        self.entries.get(key)
    }
    pub fn contains(&self, key: &str) -> bool {
        self.entries.contains_key(key)
    }
    pub fn len(&self) -> usize {
        self.entries.len()
    }
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Conventional op keys.
    pub fn key_gram(rows: usize, r: usize) -> String {
        format!("gram_{rows}x{r}")
    }
    pub fn key_xht(mi: usize, nj: usize, r: usize) -> String {
        format!("xht_{mi}x{nj}x{r}")
    }
    pub fn key_wtx(mi: usize, nj: usize, r: usize) -> String {
        format!("wtx_{mi}x{nj}x{r}")
    }
    pub fn key_bcd(rows: usize, r: usize) -> String {
        format!("bcd_{rows}x{r}")
    }
    pub fn key_mu(rows: usize, r: usize) -> String {
        format!("mu_{rows}x{r}")
    }
    pub fn key_nmf_iter(m: usize, n: usize, r: usize) -> String {
        format!("nmf_iter_bcd_{m}x{n}x{r}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_when_missing() {
        let m = Manifest::load(Path::new("/nonexistent-dir")).unwrap();
        assert!(m.is_empty());
        assert!(!m.contains("gram_6x2"));
    }

    #[test]
    fn key_formats() {
        assert_eq!(Manifest::key_gram(6, 2), "gram_6x2");
        assert_eq!(Manifest::key_xht(4, 6, 2), "xht_4x6x2");
        assert_eq!(Manifest::key_nmf_iter(8, 12, 2), "nmf_iter_bcd_8x12x2");
    }

    #[test]
    fn parse_minimal_manifest() {
        let dir = std::env::temp_dir().join(format!("dntt_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("gram_6x2.hlo.txt"), "fake").unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"dtype":"f32","ops":[{"key":"gram_6x2","op":"gram","dims":[6,2],"file":"gram_6x2.hlo.txt","outputs":1}]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.len(), 1);
        let a = m.get("gram_6x2").unwrap();
        assert_eq!(a.dims, vec![6, 2]);
        assert_eq!(a.outputs, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_file_is_error() {
        let dir = std::env::temp_dir().join(format!("dntt_manifest2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"ops":[{"key":"a","file":"nope.hlo.txt"}]}"#,
        )
        .unwrap();
        assert!(Manifest::load(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
