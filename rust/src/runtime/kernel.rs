//! Kernel-policy surface for the coordinator/CLI layer.
//!
//! The types live in [`crate::linalg::simd`] (the dispatchers need them
//! below the runtime layer); this module re-exports them and documents
//! the selection contract the job layer plumbs through.
//!
//! ## Selection precedence
//!
//! 1. **`DNTT_KERNEL` env var** ([`DNTT_KERNEL_ENV`]) — process-wide
//!    force, wins over everything. This is how the CI kernel matrix runs
//!    the whole test suite under each path.
//! 2. **`JobConfig.kernel`** / CLI `--kernel` — per-job policy.
//! 3. **`auto`** — the default: best available path at runtime.
//!
//! | policy   | executes                                   |
//! |----------|--------------------------------------------|
//! | `auto`   | best available (avx512 → avx2 → neon → scalar) |
//! | `scalar` | portable reference tile                    |
//! | `avx2`   | AVX2 tile (x86_64)                         |
//! | `avx512` | AVX2 tile (`avx512f` implies `avx2`; named for forward compat) |
//! | `neon`   | NEON tile (aarch64)                        |
//!
//! A forced path the host lacks warns and falls back to scalar. The
//! companion knob `JobConfig.threads_per_rank` sizes the intra-rank
//! thread pool that partitions output row panels (default 1).
//!
//! ## Why this is safe to flip freely
//!
//! Every path and thread count produces **bitwise identical** results
//! (the lane/thread mapping preserves each output element's accumulation
//! sequence — see `crate::linalg::simd` and DESIGN.md §3.3), so kernel
//! selection is excluded from job fingerprints: a job forced to `scalar`
//! may resume a checkpoint written under `avx2` and vice versa, and the
//! JobServer result cache is shared across policies.

pub use crate::linalg::simd::{
    default_path, KernelCfg, KernelPath, KernelPolicy, DNTT_KERNEL_ENV,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reexports_resolve() {
        // The re-exported surface is the linalg one (same types). The
        // default cfg follows the env-aware process default (which may be
        // forced by DNTT_KERNEL in the CI kernel matrix).
        assert_eq!(KernelCfg::default().path, default_path());
        assert!(KernelPolicy::Auto.resolve().is_available());
        assert!(KernelPath::Scalar.is_available());
        assert_eq!(DNTT_KERNEL_ENV, "DNTT_KERNEL");
    }
}
