//! Local compute backend abstraction.
//!
//! Every *local* (per-rank) kernel of the distributed NMF goes through this
//! trait so the same SPMD code can run on:
//!
//! * [`crate::runtime::native::NativeBackend`] — pure-Rust linalg, any shape;
//! * [`crate::runtime::pjrt::PjrtBackend`] — AOT-compiled JAX/Pallas
//!   artifacts executed through the XLA PJRT CPU client (Python never runs
//!   at execution time), falling back to native for shapes missing from the
//!   artifact manifest.
//!
//! Backends must agree numerically (asserted in `tests/integration_runtime`).
//!
//! Shape conventions (the `Ht` convention — H is stored transposed so all
//! kernels see contiguous rows):
//! * factor blocks are `rows × r` (`W` block or `Hᵀ` block);
//! * `gram(F) = Fᵀ·F` is `r × r`;
//! * `xht(X, Ht) = X·H̃` is `m_i × r` for `X: m_i × n_j`, `Ht: n_j × r`;
//! * `wtx(X, W) = Xᵀ·W` is `n_j × r`.

use crate::linalg::Mat;

/// Per-rank dense kernels used by the NMF inner loop.
pub trait ComputeBackend: Send + Sync {
    /// `Fᵀ·F` for a `rows × r` factor block → `r × r` partial Gram.
    fn gram(&self, f: &Mat<f64>) -> Mat<f64>;

    /// `X · Ht` (`m_i × n_j` times `n_j × r`) → `m_i × r` (local X·Hᵀ).
    fn xht(&self, x: &Mat<f64>, ht: &Mat<f64>) -> Mat<f64>;

    /// `Xᵀ · W` (`m_i × n_j`ᵀ times `m_i × r`) → `n_j × r` (local (WᵀX)ᵀ).
    fn wtx(&self, x: &Mat<f64>, w: &Mat<f64>) -> Mat<f64>;

    /// BCD projected-gradient step (Alg 3 lines 6–8 / 11–14):
    /// `max(0, Fm − (Fm·G − P) / lip)` where `G` is the `r×r` Gram of the
    /// other factor, `P` the `rows × r` product block and `lip` the
    /// Lipschitz step (‖G‖).
    fn bcd_update(&self, fm: &Mat<f64>, g: &Mat<f64>, p: &Mat<f64>, lip: f64) -> Mat<f64>;

    /// Multiplicative (Lee–Seung) step: `F ⊙ P ⊘ (F·G + ε)`.
    fn mu_update(&self, f: &Mat<f64>, g: &Mat<f64>, p: &Mat<f64>) -> Mat<f64>;

    /// Backend label for logs/metrics.
    fn name(&self) -> &'static str;
}

/// Small epsilon guarding MU divisions (matches the JAX kernel).
pub const MU_EPS: f64 = 1e-16;
