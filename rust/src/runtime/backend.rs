//! Local compute backend abstraction.
//!
//! Every *local* (per-rank) kernel of the distributed NMF goes through this
//! trait so the same SPMD code can run on:
//!
//! * [`crate::runtime::native::NativeBackend`] — pure-Rust linalg, any shape;
//! * [`crate::runtime::pjrt::PjrtBackend`] — AOT-compiled JAX/Pallas
//!   artifacts executed through the XLA PJRT CPU client (Python never runs
//!   at execution time), falling back to native for shapes missing from the
//!   artifact manifest.
//!
//! Backends must agree numerically (asserted in `tests/integration_runtime`).
//!
//! Shape conventions (the `Ht` convention — H is stored transposed so all
//! kernels see contiguous rows):
//! * factor blocks are `rows × r` (`W` block or `Hᵀ` block);
//! * `gram(F) = Fᵀ·F` is `r × r`;
//! * `xht(X, Ht) = X·H̃` is `m_i × r` for `X: m_i × n_j`, `Ht: n_j × r`;
//! * `wtx(X, W) = Xᵀ·W` is `n_j × r`.

use crate::linalg::sparse::{sp_matmul, sp_matmul_at_b, SparseMat};
use crate::linalg::{GemmWorkspace, Mat};

/// Reusable scratch for the per-rank kernels: GEMM packing panels plus the
/// `F·G` temporary of the BCD/MU updates. One per rank, threaded through
/// every `_into`/`_inplace` backend call so multiplicative-update
/// iterations stop allocating once the buffers reach their high-water
/// sizes (see `nmf::workspace::NmfWorkspace`, which embeds one).
#[derive(Default)]
pub struct KernelWorkspace {
    /// Packing panels for the register-blocked GEMM microkernel.
    pub gemm: GemmWorkspace<f64>,
    /// `F·G` product temporary (`rows × r`) of the update rules.
    pub fg: Mat<f64>,
}

impl KernelWorkspace {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Per-rank dense kernels used by the NMF inner loop.
///
/// The allocating methods (`gram`, `xht`, …) are the required interface;
/// the `_into`/`_inplace` variants have default implementations that fall
/// back to them, and backends that can compute without allocating (the
/// native one) override them. The two forms must agree bitwise.
pub trait ComputeBackend: Send + Sync {
    /// `Fᵀ·F` for a `rows × r` factor block → `r × r` partial Gram.
    fn gram(&self, f: &Mat<f64>) -> Mat<f64>;

    /// `X · Ht` (`m_i × n_j` times `n_j × r`) → `m_i × r` (local X·Hᵀ).
    fn xht(&self, x: &Mat<f64>, ht: &Mat<f64>) -> Mat<f64>;

    /// `Xᵀ · W` (`m_i × n_j`ᵀ times `m_i × r`) → `n_j × r` (local (WᵀX)ᵀ).
    fn wtx(&self, x: &Mat<f64>, w: &Mat<f64>) -> Mat<f64>;

    /// BCD projected-gradient step (Alg 3 lines 6–8 / 11–14):
    /// `max(0, Fm − (Fm·G − P) / lip)` where `G` is the `r×r` Gram of the
    /// other factor, `P` the `rows × r` product block and `lip` the
    /// Lipschitz step (‖G‖).
    fn bcd_update(&self, fm: &Mat<f64>, g: &Mat<f64>, p: &Mat<f64>, lip: f64) -> Mat<f64>;

    /// Multiplicative (Lee–Seung) step: `F ⊙ P ⊘ (F·G + ε)`.
    fn mu_update(&self, f: &Mat<f64>, g: &Mat<f64>, p: &Mat<f64>) -> Mat<f64>;

    /// [`ComputeBackend::gram`] into a caller buffer (resized in place).
    fn gram_into(&self, f: &Mat<f64>, out: &mut Mat<f64>, ws: &mut KernelWorkspace) {
        let _ = ws;
        *out = self.gram(f);
    }

    /// [`ComputeBackend::xht`] into a caller buffer (resized in place).
    fn xht_into(&self, x: &Mat<f64>, ht: &Mat<f64>, out: &mut Mat<f64>, ws: &mut KernelWorkspace) {
        let _ = ws;
        *out = self.xht(x, ht);
    }

    /// [`ComputeBackend::wtx`] into a caller buffer (resized in place).
    fn wtx_into(&self, x: &Mat<f64>, w: &Mat<f64>, out: &mut Mat<f64>, ws: &mut KernelWorkspace) {
        let _ = ws;
        *out = self.wtx(x, w);
    }

    /// [`ComputeBackend::bcd_update`] into a caller buffer. `fm` and `out`
    /// must be distinct matrices (the SPMD loop updates `F` from the
    /// momentum iterate `Fm`).
    fn bcd_update_into(
        &self,
        fm: &Mat<f64>,
        g: &Mat<f64>,
        p: &Mat<f64>,
        lip: f64,
        out: &mut Mat<f64>,
        ws: &mut KernelWorkspace,
    ) {
        let _ = ws;
        *out = self.bcd_update(fm, g, p, lip);
    }

    /// [`ComputeBackend::mu_update`] applied in place to `f`.
    fn mu_update_inplace(
        &self,
        f: &mut Mat<f64>,
        g: &Mat<f64>,
        p: &Mat<f64>,
        ws: &mut KernelWorkspace,
    ) {
        let _ = ws;
        *f = self.mu_update(f, g, p);
    }

    /// Sparse `X · Ht` (CSR `m_i × n_j` times dense `n_j × r`). The
    /// default allocates through [`crate::linalg::sparse::sp_matmul`];
    /// backends without a sparse path (PJRT) inherit it unchanged.
    fn xht_sparse(&self, x: &SparseMat, ht: &Mat<f64>) -> Mat<f64> {
        sp_matmul(x, ht)
    }

    /// Sparse `Xᵀ · W` (CSR `m_i × n_j` transposed times dense
    /// `m_i × r`). Allocating default, see [`ComputeBackend::xht_sparse`].
    fn wtx_sparse(&self, x: &SparseMat, w: &Mat<f64>) -> Mat<f64> {
        sp_matmul_at_b(x, w)
    }

    /// [`ComputeBackend::xht_sparse`] into a caller buffer (resized in
    /// place). Allocating default; the native backend overrides it with
    /// the zero-allocation SpMM.
    fn xht_sparse_into(
        &self,
        x: &SparseMat,
        ht: &Mat<f64>,
        out: &mut Mat<f64>,
        ws: &mut KernelWorkspace,
    ) {
        let _ = ws;
        *out = self.xht_sparse(x, ht);
    }

    /// [`ComputeBackend::wtx_sparse`] into a caller buffer (resized in
    /// place). Allocating default; the native backend overrides it.
    fn wtx_sparse_into(
        &self,
        x: &SparseMat,
        w: &Mat<f64>,
        out: &mut Mat<f64>,
        ws: &mut KernelWorkspace,
    ) {
        let _ = ws;
        *out = self.wtx_sparse(x, w);
    }

    /// Backend label for logs/metrics.
    fn name(&self) -> &'static str;
}

/// Small epsilon guarding MU divisions (matches the JAX kernel).
pub const MU_EPS: f64 = 1e-16;
