//! Tiny command-line parser (the offline substitute for `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments and
//! subcommands. Every option is declared with a help string so `--help`
//! output stays accurate; unknown options are hard errors (catching typos in
//! experiment scripts matters more than leniency).

use std::collections::BTreeMap;

/// Declared option.
#[derive(Clone)]
struct Opt {
    name: &'static str,
    help: &'static str,
    takes_value: bool,
    default: Option<String>,
}

/// Declarative parser for one (sub)command.
pub struct ArgSpec {
    program: String,
    about: &'static str,
    opts: Vec<Opt>,
    positionals: Vec<(&'static str, &'static str)>,
}

/// Parsed arguments.
#[derive(Debug)]
pub struct Args {
    values: BTreeMap<&'static str, String>,
    flags: BTreeMap<&'static str, bool>,
    positionals: Vec<String>,
}

impl ArgSpec {
    pub fn new(program: &str, about: &'static str) -> Self {
        ArgSpec { program: program.to_string(), about, opts: Vec::new(), positionals: Vec::new() }
    }

    /// Declare `--name <value>` with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, takes_value: true, default: Some(default.into()) });
        self
    }

    /// Declare a required `--name <value>`.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, takes_value: true, default: None });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, takes_value: false, default: None });
        self
    }

    /// Declare a positional argument (for help text only; all positionals
    /// are collected in order).
    pub fn pos(mut self, name: &'static str, help: &'static str) -> Self {
        self.positionals.push((name, help));
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.program, self.about, self.program);
        for (p, _) in &self.positionals {
            s.push_str(&format!(" <{p}>"));
        }
        s.push_str(" [OPTIONS]\n");
        if !self.positionals.is_empty() {
            s.push_str("\nARGS:\n");
            for (p, h) in &self.positionals {
                s.push_str(&format!("  <{p:<14}> {h}\n"));
            }
        }
        s.push_str("\nOPTIONS:\n");
        for o in &self.opts {
            let lhs = if o.takes_value {
                format!("--{} <v>", o.name)
            } else {
                format!("--{}", o.name)
            };
            let def = match &o.default {
                Some(d) if o.takes_value => format!(" [default: {d}]"),
                _ => String::new(),
            };
            s.push_str(&format!("  {lhs:<22} {}{def}\n", o.help));
        }
        s.push_str("  --help                 print this help\n");
        s
    }

    /// Parse a raw argument list (not including argv[0]).
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut values = BTreeMap::new();
        let mut flags = BTreeMap::new();
        let mut positionals = Vec::new();
        for o in &self.opts {
            if let Some(d) = &o.default {
                values.insert(o.name, d.clone());
            }
            if !o.takes_value {
                flags.insert(o.name, false);
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n\n{}", self.usage()))?;
                if opt.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} requires a value"))?
                        }
                    };
                    values.insert(opt.name, v);
                } else {
                    if inline.is_some() {
                        return Err(format!("--{name} does not take a value"));
                    }
                    flags.insert(opt.name, true);
                }
            } else {
                positionals.push(a.clone());
            }
            i += 1;
        }
        // Required options.
        for o in &self.opts {
            if o.takes_value && o.default.is_none() && !values.contains_key(o.name) {
                return Err(format!("missing required --{}\n\n{}", o.name, self.usage()));
            }
        }
        Ok(Args { values, flags, positionals })
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} was not declared"))
    }
    pub fn flag(&self, name: &str) -> bool {
        *self.flags.get(name).unwrap_or(&false)
    }
    pub fn usize(&self, name: &str) -> Result<usize, String> {
        self.get(name).parse().map_err(|_| format!("--{name} must be an integer"))
    }
    pub fn f64(&self, name: &str) -> Result<f64, String> {
        self.get(name).parse().map_err(|_| format!("--{name} must be a number"))
    }
    /// Parse a comma-separated list of usize, e.g. `--dims 32,32,32,32`.
    pub fn usize_list(&self, name: &str) -> Result<Vec<usize>, String> {
        self.get(name)
            .split(',')
            .map(|s| s.trim().parse().map_err(|_| format!("--{name}: bad integer '{s}'")))
            .collect()
    }
    /// Parse a comma-separated list of f64.
    pub fn f64_list(&self, name: &str) -> Result<Vec<f64>, String> {
        self.get(name)
            .split(',')
            .map(|s| s.trim().parse().map_err(|_| format!("--{name}: bad number '{s}'")))
            .collect()
    }
    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("dntt decompose", "decompose a tensor")
            .opt("dims", "32,32,32,32", "tensor dimensions")
            .opt("eps", "0.01", "target relative error")
            .req("out", "output path")
            .flag("verbose", "chatty output")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_required() {
        let a = spec().parse(&sv(&["--out", "x.json"])).unwrap();
        assert_eq!(a.get("dims"), "32,32,32,32");
        assert_eq!(a.f64("eps").unwrap(), 0.01);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(spec().parse(&sv(&[])).is_err());
    }

    #[test]
    fn equals_form_and_flags() {
        let a = spec().parse(&sv(&["--out=o", "--eps=0.5", "--verbose"])).unwrap();
        assert_eq!(a.f64("eps").unwrap(), 0.5);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(spec().parse(&sv(&["--out", "o", "--nope"])).is_err());
    }

    #[test]
    fn lists() {
        let a = spec().parse(&sv(&["--out", "o", "--dims", "4, 8,16"])).unwrap();
        assert_eq!(a.usize_list("dims").unwrap(), vec![4, 8, 16]);
    }

    #[test]
    fn positionals_collected() {
        let a = spec().parse(&sv(&["--out", "o", "input.bin"])).unwrap();
        assert_eq!(a.positionals(), &["input.bin".to_string()]);
    }

    #[test]
    fn help_is_err_with_usage() {
        let e = spec().parse(&sv(&["--help"])).unwrap_err();
        assert!(e.contains("USAGE"));
        assert!(e.contains("--eps"));
    }
}
