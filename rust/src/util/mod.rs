//! Cross-cutting substrates: PRNG, JSON, CLI parsing, timing, logging,
//! property-test driver. These replace crates (`rand`, `serde_json`,
//! `clap`, `env_logger`, `proptest`) that are unreachable in the offline
//! build environment — see DESIGN.md §4.

pub mod argparse;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod timer;
