//! Minimal JSON reader/writer.
//!
//! Used for the artifact manifest (`artifacts/manifest.json`, produced by
//! `python/compile/aot.py`), experiment configuration files, and machine-
//! readable benchmark output. Implements the full JSON grammar (RFC 8259)
//! for parsing; serialization covers everything dnTT emits.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a `BTreeMap` so output is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Object field access; returns `Json::Null` when missing.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }

    /// Build an object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are not needed for our manifests;
                            // map unpaired surrogates to U+FFFD.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalar_values() {
        for text in ["null", "true", "false", "0", "-1.5", "1e3", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").as_str().unwrap(), "x\ny");
    }

    #[test]
    fn pretty_roundtrip() {
        let v = Json::obj(vec![
            ("shapes", Json::arr_usize(&[4, 8, 16])),
            ("name", Json::Str("gram".into())),
            ("eps", Json::Num(1e-3)),
        ]);
        let v2 = Json::parse(&v.to_pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn escapes() {
        let s = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&s.to_string()).unwrap(), s);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn get_missing_is_null() {
        let v = Json::parse("{}").unwrap();
        assert_eq!(*v.get("nope"), Json::Null);
    }
}
