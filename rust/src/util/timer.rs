//! Instrumented timing with the paper's cost categories.
//!
//! §IV-B of the paper breaks the TT runtime into compute categories
//! (GR, MM, MAD, Norm, INIT), communication categories (AG, AR, RSC) and
//! data-movement (I/O, reshape). Every rank accumulates a [`Breakdown`];
//! the coordinator merges them (SPMD time = max over ranks per category)
//! and prints the same tables the paper plots in Figs 5–7.

use std::time::Instant;

/// Cost category, matching the paper's legend.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(usize)]
pub enum Cat {
    /// GR — local Gram matrix computation (`W^T W` or `H H^T`).
    Gram = 0,
    /// MM — local matrix-matrix multiplications (`X H^T`, `W^T X`, updates).
    MatMul = 1,
    /// MAD — element-wise multiply/add/divide and projections.
    Mad = 2,
    /// Norm — local norm computations.
    Norm = 3,
    /// INIT — factor initialization.
    Init = 4,
    /// AG — all_gather communication.
    AllGather = 5,
    /// AR — all_reduce communication.
    AllReduce = 6,
    /// RSC — reduce_scatter communication.
    ReduceScatter = 7,
    /// I/O — chunk-store reads/writes.
    Io = 8,
    /// Reshape — distributed reshape index mapping + copies.
    Reshape = 9,
    /// SVD — distributed rank-selection SVD.
    Svd = 10,
    /// Everything else (driver logic, etc.).
    Other = 11,
}

pub const NUM_CATS: usize = 12;

pub const ALL_CATS: [Cat; NUM_CATS] = [
    Cat::Gram,
    Cat::MatMul,
    Cat::Mad,
    Cat::Norm,
    Cat::Init,
    Cat::AllGather,
    Cat::AllReduce,
    Cat::ReduceScatter,
    Cat::Io,
    Cat::Reshape,
    Cat::Svd,
    Cat::Other,
];

impl Cat {
    /// Paper-legend short name.
    pub fn name(self) -> &'static str {
        match self {
            Cat::Gram => "GR",
            Cat::MatMul => "MM",
            Cat::Mad => "MAD",
            Cat::Norm => "Norm",
            Cat::Init => "INIT",
            Cat::AllGather => "AG",
            Cat::AllReduce => "AR",
            Cat::ReduceScatter => "RSC",
            Cat::Io => "IO",
            Cat::Reshape => "Reshape",
            Cat::Svd => "SVD",
            Cat::Other => "Other",
        }
    }

    /// True for the communication categories (AG/AR/RSC).
    pub fn is_comm(self) -> bool {
        matches!(self, Cat::AllGather | Cat::AllReduce | Cat::ReduceScatter)
    }

    /// True for the local-compute categories.
    pub fn is_compute(self) -> bool {
        matches!(self, Cat::Gram | Cat::MatMul | Cat::Mad | Cat::Norm | Cat::Init)
    }
}

/// Per-rank accumulated costs.
#[derive(Clone, Debug, Default)]
pub struct Breakdown {
    secs: [f64; NUM_CATS],
    calls: [u64; NUM_CATS],
    /// Bytes moved, for communication / IO categories (used by the α-β model).
    bytes: [u64; NUM_CATS],
}

impl Breakdown {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under a category.
    #[inline]
    pub fn time<R>(&mut self, cat: Cat, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.add_secs(cat, t0.elapsed().as_secs_f64());
        r
    }

    #[inline]
    pub fn add_secs(&mut self, cat: Cat, secs: f64) {
        self.secs[cat as usize] += secs;
        self.calls[cat as usize] += 1;
    }

    #[inline]
    pub fn add_bytes(&mut self, cat: Cat, bytes: u64) {
        self.bytes[cat as usize] += bytes;
    }

    /// Bump the call counter without adding time (used by the cost model
    /// to carry measured call counts into a modeled breakdown).
    #[inline]
    pub fn add_calls(&mut self, cat: Cat, calls: u64) {
        self.calls[cat as usize] += calls;
    }

    /// Add seconds without bumping the call counter (the cost model
    /// reconstructs modeled time and carries call counts separately).
    #[inline]
    pub fn add_secs_untallied(&mut self, cat: Cat, secs: f64) {
        self.secs[cat as usize] += secs;
    }

    pub fn secs(&self, cat: Cat) -> f64 {
        self.secs[cat as usize]
    }
    pub fn calls(&self, cat: Cat) -> u64 {
        self.calls[cat as usize]
    }
    pub fn bytes(&self, cat: Cat) -> u64 {
        self.bytes[cat as usize]
    }

    pub fn total_secs(&self) -> f64 {
        self.secs.iter().sum()
    }
    pub fn compute_secs(&self) -> f64 {
        ALL_CATS.iter().filter(|c| c.is_compute()).map(|&c| self.secs(c)).sum()
    }
    pub fn comm_secs(&self) -> f64 {
        ALL_CATS.iter().filter(|c| c.is_comm()).map(|&c| self.secs(c)).sum()
    }

    /// SPMD merge: per-category max over ranks (the critical path).
    pub fn merge_max(&mut self, other: &Breakdown) {
        for i in 0..NUM_CATS {
            self.secs[i] = self.secs[i].max(other.secs[i]);
            self.calls[i] = self.calls[i].max(other.calls[i]);
            self.bytes[i] = self.bytes[i].max(other.bytes[i]);
        }
    }

    /// Aggregate merge: per-category sum (total work).
    pub fn merge_sum(&mut self, other: &Breakdown) {
        for i in 0..NUM_CATS {
            self.secs[i] += other.secs[i];
            self.calls[i] += other.calls[i];
            self.bytes[i] += other.bytes[i];
        }
    }

    /// Render a paper-style table (category, time, calls, bytes).
    pub fn table(&self) -> String {
        let mut s = String::from("category      time(s)      calls      bytes\n");
        for &c in &ALL_CATS {
            if self.calls(c) == 0 && self.secs(c) == 0.0 {
                continue;
            }
            s.push_str(&format!(
                "{:<10} {:>10.4} {:>10} {:>12}\n",
                c.name(),
                self.secs(c),
                self.calls(c),
                self.bytes(c)
            ));
        }
        s.push_str(&format!(
            "{:<10} {:>10.4}   (compute {:.4}, comm {:.4})\n",
            "TOTAL",
            self.total_secs(),
            self.compute_secs(),
            self.comm_secs()
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_accumulates() {
        let mut b = Breakdown::new();
        let x = b.time(Cat::Gram, || 21 * 2);
        assert_eq!(x, 42);
        assert_eq!(b.calls(Cat::Gram), 1);
        assert!(b.secs(Cat::Gram) >= 0.0);
    }

    #[test]
    fn merge_max_takes_critical_path() {
        let mut a = Breakdown::new();
        a.add_secs(Cat::MatMul, 2.0);
        let mut b = Breakdown::new();
        b.add_secs(Cat::MatMul, 3.0);
        b.add_secs(Cat::AllGather, 1.0);
        a.merge_max(&b);
        assert_eq!(a.secs(Cat::MatMul), 3.0);
        assert_eq!(a.secs(Cat::AllGather), 1.0);
    }

    #[test]
    fn merge_sum_accumulates() {
        let mut a = Breakdown::new();
        a.add_secs(Cat::Io, 1.0);
        let mut b = Breakdown::new();
        b.add_secs(Cat::Io, 2.5);
        a.merge_sum(&b);
        assert_eq!(a.secs(Cat::Io), 3.5);
    }

    #[test]
    fn compute_comm_split() {
        let mut b = Breakdown::new();
        b.add_secs(Cat::Gram, 1.0);
        b.add_secs(Cat::AllReduce, 2.0);
        b.add_secs(Cat::Io, 4.0);
        assert_eq!(b.compute_secs(), 1.0);
        assert_eq!(b.comm_secs(), 2.0);
        assert_eq!(b.total_secs(), 7.0);
    }

    #[test]
    fn table_renders_nonzero_rows() {
        let mut b = Breakdown::new();
        b.add_secs(Cat::Gram, 1.0);
        let t = b.table();
        assert!(t.contains("GR"));
        assert!(!t.contains("RSC"));
    }

    #[test]
    fn bytes_tracked() {
        let mut b = Breakdown::new();
        b.add_bytes(Cat::AllGather, 1024);
        b.add_bytes(Cat::AllGather, 1024);
        assert_eq!(b.bytes(Cat::AllGather), 2048);
    }
}
