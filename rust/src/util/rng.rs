//! Deterministic pseudo-random number generation.
//!
//! Offline builds cannot pull the `rand` crate family, so the library ships
//! its own generator: **xoshiro256\*\*** (Blackman–Vigna), seeded through
//! SplitMix64 — the standard, well-tested combination. Every stochastic
//! component of dnTT (factor initialization, synthetic tensors, noise,
//! property tests) goes through [`Rng`], so runs are reproducible from a
//! single `u64` seed and independent streams can be split per MPI-style rank.

/// xoshiro256** generator. 2^256-1 period, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from Box–Muller.
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream for a given rank/label.
    ///
    /// Uses the (seed, stream) pair through SplitMix64 so that streams for
    /// different ranks are decorrelated — the thread-rank analogue of
    /// per-MPI-rank seeding in the paper's implementation.
    pub fn split(&self, stream: u64) -> Rng {
        let mut sm = self.s[0] ^ stream.wrapping_mul(0xA076_1D64_78BD_642F);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for practical purposes (n << 2^64).
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid u == 0 so ln(u) is finite.
        let u = loop {
            let u = self.uniform();
            if u > 0.0 {
                break u;
            }
        };
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with mean/stddev.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fill a slice with uniform [0,1) values.
    pub fn fill_uniform(&mut self, buf: &mut [f64]) {
        for x in buf.iter_mut() {
            *x = self.uniform();
        }
    }

    /// Random permutation of 0..n (Fisher–Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            p.swap(i, self.below(i + 1));
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn split_streams_are_decorrelated() {
        let root = Rng::new(7);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(13);
        let p = r.permutation(50);
        let mut seen = vec![false; 50];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }
}
