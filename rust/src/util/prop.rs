//! Lightweight property-testing driver (offline substitute for `proptest`).
//!
//! Runs a property over many PRNG-generated cases; on failure it reports the
//! case index and the seed that reproduces it, so failures are one-line
//! reproducible: `check_with_seed(<seed>, ...)`.

use super::rng::Rng;

/// Default number of cases per property.
pub const DEFAULT_CASES: usize = 64;

/// Run `prop` on `cases` random inputs derived from `seed`.
///
/// `prop` receives a per-case RNG and returns `Err(msg)` to fail. Panics
/// inside the property are *not* caught (the test harness reports them with
/// the case banner printed beforehand via `eprintln!` on failure paths).
pub fn check_cases(seed: u64, cases: usize, mut prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    let root = Rng::new(seed);
    for case in 0..cases {
        let mut rng = root.split(case as u64);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property failed at case {case} (reproduce with seed={seed}, case={case}): {msg}"
            );
        }
    }
}

/// Run with the default case count.
pub fn check(seed: u64, prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    check_cases(seed, DEFAULT_CASES, prop)
}

/// Helper: assert two f64 slices are close within `tol` (absolute+relative).
pub fn assert_close(a: &[f64], b: &[f64], tol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let scale = 1.0_f64.max(x.abs()).max(y.abs());
        if (x - y).abs() > tol * scale {
            return Err(format!("element {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(1, |rng| {
            let x = rng.uniform();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(2, |rng| {
            let x = rng.uniform();
            if x < 0.5 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }

    #[test]
    fn assert_close_catches_mismatch() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-12], 1e-9).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-9).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-9).is_err());
    }
}
