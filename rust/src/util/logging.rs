//! Minimal `log`-facade backend (stderr, level from `DNTT_LOG`).
//!
//! The offline environment has the `log` facade but no `env_logger`, so the
//! library ships a small implementation. Level is read once from the
//! `DNTT_LOG` environment variable (`error|warn|info|debug|trace`,
//! default `info`).

use log::{Level, LevelFilter, Log, Metadata, Record};
use std::sync::Once;

struct StderrLogger {
    level: Level,
}

impl Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata<'_>) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record<'_>) {
        if self.enabled(record.metadata()) {
            eprintln!("[{:<5} {}] {}", record.level(), record.target(), record.args());
        }
    }

    fn flush(&self) {}
}

static INIT: Once = Once::new();

/// Install the logger (idempotent). Call at the top of binaries.
pub fn init() {
    INIT.call_once(|| {
        let level = match std::env::var("DNTT_LOG").as_deref() {
            Ok("error") => Level::Error,
            Ok("warn") => Level::Warn,
            Ok("debug") => Level::Debug,
            Ok("trace") => Level::Trace,
            _ => Level::Info,
        };
        let logger = Box::leak(Box::new(StderrLogger { level }));
        if log::set_logger(logger).is_ok() {
            log::set_max_level(LevelFilter::from(level.to_level_filter()));
        }
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging works");
    }
}
