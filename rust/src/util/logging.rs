//! Minimal `log`-facade backend (stderr, level from `DNTT_LOG`).
//!
//! The offline environment has the `log` facade but no `env_logger`, so the
//! library ships a small implementation. Level is read once from the
//! `DNTT_LOG` environment variable (`off|error|warn|info|debug|trace`,
//! default `info`; anything else warns once and falls back to `info`).
//!
//! Records are prefixed with the milliseconds elapsed since [`init`] and
//! the emitting world rank, so interleaved multi-rank stderr is
//! attributable:
//!
//! ```text
//! [   12.3ms r3 WARN  dntt::dist::checkpoint] manifest commit retried
//! [   12.4ms -- INFO  dntt::coordinator] job finished
//! ```
//!
//! The rank slot is a thread-local installed by [`crate::dist::Comm::run`]
//! on every rank thread (via [`set_thread_rank`]) and cleared when the
//! rank exits; threads outside a world — the coordinator itself, tests,
//! the CLI — print `--`.

use log::{Level, LevelFilter, Log, Metadata, Record};
use std::cell::Cell;
use std::sync::{Once, OnceLock};
use std::time::Instant;

/// Epoch for the elapsed-ms prefix (set once, at first [`init`]).
static EPOCH: OnceLock<Instant> = OnceLock::new();

thread_local! {
    /// World rank of the current thread, if it is a rank thread.
    static THREAD_RANK: Cell<Option<usize>> = const { Cell::new(None) };
}

/// Install `rank` as this thread's log attribution (called by
/// [`crate::dist::Comm::run`] when a rank thread starts).
pub fn set_thread_rank(rank: usize) {
    THREAD_RANK.with(|r| r.set(Some(rank)));
}

/// Clear the rank attribution (called when a rank thread exits).
pub fn clear_thread_rank() {
    THREAD_RANK.with(|r| r.set(None));
}

/// The rank installed on this thread, if any.
pub fn thread_rank() -> Option<usize> {
    THREAD_RANK.with(|r| r.get())
}

struct StderrLogger {
    level: Level,
}

impl Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata<'_>) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record<'_>) {
        if self.enabled(record.metadata()) {
            let ms = EPOCH
                .get()
                .map(|e| e.elapsed().as_secs_f64() * 1e3)
                .unwrap_or(0.0);
            let rank = match thread_rank() {
                Some(r) => format!("r{r}"),
                None => "--".to_string(),
            };
            eprintln!(
                "[{ms:>8.1}ms {rank} {:<5} {}] {}",
                record.level(),
                record.target(),
                record.args()
            );
        }
    }

    fn flush(&self) {}
}

static INIT: Once = Once::new();

/// Install the logger (idempotent). Call at the top of binaries.
pub fn init() {
    INIT.call_once(|| {
        let _ = EPOCH.set(Instant::now());
        let var = std::env::var("DNTT_LOG");
        let (filter, level, bad) = match var.as_deref() {
            Ok("off") => (LevelFilter::Off, Level::Error, None),
            Ok("error") => (LevelFilter::Error, Level::Error, None),
            Ok("warn") => (LevelFilter::Warn, Level::Warn, None),
            Ok("info") | Err(_) => (LevelFilter::Info, Level::Info, None),
            Ok("debug") => (LevelFilter::Debug, Level::Debug, None),
            Ok("trace") => (LevelFilter::Trace, Level::Trace, None),
            Ok(other) => (LevelFilter::Info, Level::Info, Some(other.to_string())),
        };
        let logger = Box::leak(Box::new(StderrLogger { level }));
        if log::set_logger(logger).is_ok() {
            log::set_max_level(filter);
        }
        if let Some(bad) = bad {
            log::warn!(
                "DNTT_LOG={bad:?} is not a level \
                 (off|error|warn|info|debug|trace); using info"
            );
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging works");
    }

    #[test]
    fn thread_rank_slot_is_thread_local() {
        assert_eq!(thread_rank(), None);
        set_thread_rank(7);
        assert_eq!(thread_rank(), Some(7));
        let other = std::thread::spawn(thread_rank).join().unwrap();
        assert_eq!(other, None, "rank attribution must not leak across threads");
        clear_thread_rank();
        assert_eq!(thread_rank(), None);
    }

    #[test]
    fn rank_threads_are_attributed_inside_a_world() {
        let ranks = crate::dist::Comm::run(3, |c| {
            log::info!("hello from a rank");
            thread_rank().map(|r| (r, c.rank()))
        });
        assert_eq!(
            ranks,
            vec![Some((0, 0)), Some((1, 1)), Some((2, 2))],
            "each rank thread sees its own rank id"
        );
        assert_eq!(thread_rank(), None, "coordinator thread stays unattributed");
    }
}
