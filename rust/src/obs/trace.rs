//! Per-rank event rings, the trace collector, and Chrome trace export.
//!
//! # Event model
//!
//! Every instrumented site records one **closed span** — an [`Event`]
//! with begin/end timestamps taken from a single job-wide epoch — into
//! the ring of the rank thread it ran on. Rings are fixed-capacity
//! `Vec`s preallocated at rank entry: the hot path is a bounds check and
//! a `Copy` write, never an allocation; events past capacity bump
//! [`RankTrace::dropped`] instead. Counters ([`crate::obs::Ctr`]) live in
//! the same thread-local state, so neither layer takes a lock while the
//! job runs.
//!
//! # Ring/merge protocol
//!
//! The coordinator [`arm`]s a [`TraceCollector`] in a thread-local slot;
//! [`crate::dist::Comm::run`] reads that slot on the spawning thread and
//! hands each rank thread a clone (the same scoping the fault injector
//! uses, so concurrent tests never observe each other's collectors). At
//! rank exit the ring is moved — not copied — into the collector under a
//! single mutex acquisition; [`TraceCollector::take_report`] then drains
//! everything into an [`ObsReport`]. Relaunched attempts append further
//! `RankTrace`s for the same rank id; the report aggregates them.
//!
//! # Neutrality guarantee
//!
//! Instrumentation only *reads* the computation: no hook touches factor
//! data, and arming a collector changes no arithmetic, no iteration
//! order, and no collective schedule. `tests/obs_neutrality.rs` asserts
//! the resulting factors are bitwise-identical to an uninstrumented run.
//! Building with `--no-default-features` removes the plumbing entirely:
//! every hook below compiles to an empty `#[inline(always)]` function,
//! the same zero-cost pattern as [`crate::dist::faults`].

use crate::obs::metrics::{counters_json, Ctr, NUM_CTRS};
use crate::util::json::Json;
use crate::util::timer::Cat;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// `true` when the crate was built with the (default) `trace` feature.
pub const TRACE_ENABLED: bool = cfg!(feature = "trace");

/// Label value meaning "the [`SpanKind`] alone names this event".
pub const NO_LABEL: u32 = u32::MAX;

/// Sizing knobs for the per-rank trace rings.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceConfig {
    /// Fixed per-rank event capacity. Each slot is one [`Event`]
    /// (40 bytes); overflow is counted, never allocated.
    pub ring_capacity: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { ring_capacity: 1 << 16 }
    }
}

/// What a span measured. Determines the Chrome-trace category and which
/// counters the closing hook bumps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// One driver stage (TT stage / HT node half); labelled with the
    /// stage name (`tt.stage0`, `ht.n3.a`, …).
    Stage,
    /// One NMF inner iteration; `arg` is the 1-based iteration index.
    NmfIter,
    /// All-gather collective; `arg` is bytes gathered.
    AllGather,
    /// All-reduce collective; `arg` is bytes reduced.
    AllReduce,
    /// Reduce-scatter collective; `arg` is bytes scattered.
    ReduceScatter,
    /// Barrier (no payload).
    Barrier,
    /// Chunk-store publish; `arg` is logical bytes stored.
    StoreWrite,
    /// Spill-file load into a store view; `arg` is bytes read.
    StoreRead,
    /// Durable checkpoint commit; `arg` is chunk bytes written.
    Checkpoint,
    /// Serve-side batched query; `arg` is the query count.
    QueryBatch,
}

impl SpanKind {
    /// Stable name used for Chrome-trace `name`/`cat` fields.
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Stage => "stage",
            SpanKind::NmfIter => "nmf_iter",
            SpanKind::AllGather => "all_gather",
            SpanKind::AllReduce => "all_reduce",
            SpanKind::ReduceScatter => "reduce_scatter",
            SpanKind::Barrier => "barrier",
            SpanKind::StoreWrite => "store_write",
            SpanKind::StoreRead => "store_read",
            SpanKind::Checkpoint => "checkpoint",
            SpanKind::QueryBatch => "query_batch",
        }
    }

    /// The span kind recording a collective of breakdown category `cat`
    /// (barrier and object gathers fold into their nearest kind).
    pub fn of_cat(cat: Cat) -> SpanKind {
        match cat {
            Cat::AllGather => SpanKind::AllGather,
            Cat::AllReduce => SpanKind::AllReduce,
            Cat::ReduceScatter => SpanKind::ReduceScatter,
            _ => SpanKind::Barrier,
        }
    }
}

/// One closed span in a rank's ring. `Copy`, fixed-size: pushing one is
/// the entirety of the hot-path cost.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub kind: SpanKind,
    /// Index into [`RankTrace::names`], or [`NO_LABEL`].
    pub label: u32,
    /// Kind-specific payload (bytes, iteration index, query count).
    pub arg: u64,
    /// Span begin, nanoseconds since the collector epoch.
    pub t0_ns: u64,
    /// Span end, nanoseconds since the collector epoch.
    pub t1_ns: u64,
}

/// Everything one rank thread recorded during one world attempt.
pub struct RankTrace {
    /// World rank (Chrome-trace `tid`).
    pub rank: usize,
    /// Closed spans, in completion order.
    pub events: Vec<Event>,
    /// Interned span labels ([`Event::label`] indexes this).
    pub names: Vec<String>,
    /// Events discarded because the ring was full.
    pub dropped: u64,
    /// Spans begun but never closed (non-zero only if the rank
    /// unwound mid-span; exported so tests can assert balance).
    pub open_spans: u64,
    /// Metric counters, indexed by [`Ctr`].
    pub counters: [u64; NUM_CTRS],
}

/// Shared sink the coordinator arms for one job: a common epoch plus the
/// merged rings of every rank thread that ran under it.
pub struct TraceCollector {
    config: TraceConfig,
    #[cfg_attr(not(feature = "trace"), allow(dead_code))]
    epoch: Instant,
    ranks: Mutex<Vec<RankTrace>>,
}

impl TraceCollector {
    /// A fresh collector; its creation instant is the trace epoch.
    pub fn new(config: TraceConfig) -> Arc<TraceCollector> {
        Arc::new(TraceCollector {
            config,
            epoch: Instant::now(),
            ranks: Mutex::new(Vec::new()),
        })
    }

    /// Ring sizing this collector hands to entering ranks.
    pub fn config(&self) -> TraceConfig {
        self.config
    }

    /// Move one rank's finished ring in (called from `exit_rank`).
    #[cfg_attr(not(feature = "trace"), allow(dead_code))]
    fn merge(&self, trace: RankTrace) {
        self.ranks.lock().unwrap().push(trace);
    }

    /// Drain everything merged so far, ordered by rank id (relaunch
    /// attempts of the same rank stay in arrival order after it).
    pub fn take_report(&self) -> ObsReport {
        let mut ranks = std::mem::take(&mut *self.ranks.lock().unwrap());
        ranks.sort_by_key(|r| r.rank);
        ObsReport { ring_capacity: self.config.ring_capacity, ranks }
    }
}

/// The merged observability record of one job: every rank's events and
/// counters, ready for export.
pub struct ObsReport {
    /// Ring capacity the traces were recorded under.
    pub ring_capacity: usize,
    /// Per-rank traces, ordered by rank id.
    pub ranks: Vec<RankTrace>,
}

impl ObsReport {
    /// Distinct rank ids present, ascending.
    pub fn rank_ids(&self) -> Vec<usize> {
        let mut ids: Vec<usize> = self.ranks.iter().map(|r| r.rank).collect();
        ids.dedup();
        ids
    }

    /// Job-total value of one counter.
    pub fn total(&self, c: Ctr) -> u64 {
        self.ranks.iter().map(|r| r.counters[c as usize]).sum()
    }

    /// Counter totals aggregated per rank id (relaunch attempts summed).
    pub fn per_rank_counters(&self) -> Vec<(usize, [u64; NUM_CTRS])> {
        let mut out: Vec<(usize, [u64; NUM_CTRS])> = Vec::new();
        for tr in &self.ranks {
            match out.last_mut() {
                Some((rank, acc)) if *rank == tr.rank => {
                    for (a, c) in acc.iter_mut().zip(tr.counters.iter()) {
                        *a += c;
                    }
                }
                _ => out.push((tr.rank, tr.counters)),
            }
        }
        out
    }

    /// Total events recorded across all ranks.
    pub fn events_total(&self) -> u64 {
        self.ranks.iter().map(|r| r.events.len() as u64).sum()
    }

    /// Total events lost to full rings.
    pub fn dropped_total(&self) -> u64 {
        self.ranks.iter().map(|r| r.dropped).sum()
    }

    /// Total spans left open at rank exit (0 on a clean run).
    pub fn open_spans_total(&self) -> u64 {
        self.ranks.iter().map(|r| r.open_spans).sum()
    }

    /// Export as Chrome trace-event JSON (the `traceEvents` object
    /// form), loadable in Perfetto / `chrome://tracing`. One process,
    /// one thread lane per rank; spans become complete (`"X"`) events
    /// with microsecond timestamps.
    pub fn chrome_trace_json(&self) -> Json {
        let mut events: Vec<Json> = Vec::new();
        for rank in self.rank_ids() {
            events.push(Json::obj(vec![
                ("name", Json::Str("thread_name".into())),
                ("ph", Json::Str("M".into())),
                ("pid", Json::Num(0.0)),
                ("tid", Json::Num(rank as f64)),
                (
                    "args",
                    Json::obj(vec![(
                        "name",
                        Json::Str(format!("rank {rank}")),
                    )]),
                ),
            ]));
        }
        for tr in &self.ranks {
            for ev in &tr.events {
                let name = if ev.label == NO_LABEL {
                    ev.kind.name().to_string()
                } else {
                    tr.names[ev.label as usize].clone()
                };
                events.push(Json::obj(vec![
                    ("name", Json::Str(name)),
                    ("cat", Json::Str(ev.kind.name().into())),
                    ("ph", Json::Str("X".into())),
                    ("ts", Json::Num(ev.t0_ns as f64 / 1000.0)),
                    (
                        "dur",
                        Json::Num((ev.t1_ns - ev.t0_ns) as f64 / 1000.0),
                    ),
                    ("pid", Json::Num(0.0)),
                    ("tid", Json::Num(tr.rank as f64)),
                    (
                        "args",
                        Json::obj(vec![("arg", Json::Num(ev.arg as f64))]),
                    ),
                ]));
            }
        }
        Json::obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", Json::Str("ms".into())),
            (
                "otherData",
                Json::obj(vec![
                    ("format", Json::Str("dntt-trace-v1".into())),
                    (
                        "ring_capacity",
                        Json::Num(self.ring_capacity as f64),
                    ),
                    ("dropped", Json::Num(self.dropped_total() as f64)),
                    (
                        "open_spans",
                        Json::Num(self.open_spans_total() as f64),
                    ),
                ]),
            ),
        ])
    }

    /// Counter totals + per-rank arrays as JSON (the `counters` section
    /// of the `dntt-metrics-v1` envelope).
    pub fn counters_section_json(&self) -> Json {
        let mut totals = [0u64; NUM_CTRS];
        for (_, ctrs) in self.per_rank_counters() {
            for (t, c) in totals.iter_mut().zip(ctrs.iter()) {
                *t += c;
            }
        }
        let per_rank: Vec<Json> = self
            .per_rank_counters()
            .into_iter()
            .map(|(rank, ctrs)| {
                Json::obj(vec![
                    ("rank", Json::Num(rank as f64)),
                    ("counters", counters_json(&ctrs)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("totals", counters_json(&totals)),
            ("per_rank", Json::Arr(per_rank)),
        ])
    }
}

// ---------------------------------------------------------------------------
// Feature-gated plumbing. Without the `trace` feature every hook below is
// an inline no-op and `armed` returns `None`, so instrumented call sites
// compile to nothing — the same shape as `dist::faults`.
// ---------------------------------------------------------------------------

#[cfg(feature = "trace")]
mod plumbing {
    use super::{Event, RankTrace, SpanKind, TraceCollector, NO_LABEL};
    use crate::obs::metrics::{Ctr, NUM_CTRS};
    use crate::util::timer::Cat;
    use std::cell::RefCell;
    use std::sync::Arc;
    use std::time::Instant;

    struct RankObs {
        collector: Arc<TraceCollector>,
        rank: usize,
        epoch: Instant,
        capacity: usize,
        events: Vec<Event>,
        names: Vec<String>,
        dropped: u64,
        open_spans: u64,
        counters: [u64; NUM_CTRS],
    }

    impl RankObs {
        fn push(&mut self, ev: Event) {
            if self.events.len() < self.capacity {
                self.events.push(ev);
            } else {
                self.dropped += 1;
            }
        }

        fn intern(&mut self, name: &str) -> u32 {
            match self.names.iter().position(|n| n == name) {
                Some(i) => i as u32,
                None => {
                    self.names.push(name.to_string());
                    (self.names.len() - 1) as u32
                }
            }
        }

        fn bump(&mut self, c: Ctr, delta: u64) {
            self.counters[c as usize] += delta;
        }
    }

    thread_local! {
        /// Coordinator-thread slot: the collector worlds started from
        /// this thread will observe.
        static ARMED: RefCell<Option<Arc<TraceCollector>>> =
            const { RefCell::new(None) };
        /// Rank-thread slot: this rank's ring + counters.
        static RANK: RefCell<Option<RankObs>> = const { RefCell::new(None) };
    }

    pub fn arm(collector: &Arc<TraceCollector>) {
        ARMED.with(|a| *a.borrow_mut() = Some(Arc::clone(collector)));
    }

    pub fn disarm() {
        ARMED.with(|a| *a.borrow_mut() = None);
    }

    pub fn armed() -> Option<Arc<TraceCollector>> {
        ARMED.with(|a| a.borrow().clone())
    }

    pub fn enter_rank(collector: Option<Arc<TraceCollector>>, rank: usize) {
        RANK.with(|r| {
            *r.borrow_mut() = collector.map(|collector| {
                let capacity = collector.config.ring_capacity;
                RankObs {
                    epoch: collector.epoch,
                    rank,
                    capacity,
                    events: Vec::with_capacity(capacity),
                    names: Vec::new(),
                    dropped: 0,
                    open_spans: 0,
                    counters: [0; NUM_CTRS],
                    collector,
                }
            });
        });
    }

    pub fn exit_rank() {
        RANK.with(|r| {
            if let Some(st) = r.borrow_mut().take() {
                st.collector.merge(RankTrace {
                    rank: st.rank,
                    events: st.events,
                    names: st.names,
                    dropped: st.dropped,
                    open_spans: st.open_spans,
                    counters: st.counters,
                });
            }
        });
    }

    /// Begin-of-span marker. Inactive (and free) when the thread is not
    /// an observed rank.
    #[derive(Debug)]
    pub struct SpanToken {
        t0_ns: u64,
        active: bool,
    }

    pub fn span_begin() -> SpanToken {
        RANK.with(|r| match r.borrow_mut().as_mut() {
            Some(st) => {
                st.open_spans += 1;
                SpanToken {
                    t0_ns: st.epoch.elapsed().as_nanos() as u64,
                    active: true,
                }
            }
            None => SpanToken { t0_ns: 0, active: false },
        })
    }

    /// Close `tok` as one event; returns the span duration so callers
    /// can bump their own `*_ns` counters. No-op on inactive tokens.
    fn close(tok: SpanToken, kind: SpanKind, label: u32, arg: u64) -> u64 {
        if !tok.active {
            return 0;
        }
        RANK.with(|r| {
            let mut r = r.borrow_mut();
            let Some(st) = r.as_mut() else { return 0 };
            let t1_ns = st.epoch.elapsed().as_nanos() as u64;
            st.open_spans -= 1;
            st.push(Event { kind, label, arg, t0_ns: tok.t0_ns, t1_ns });
            t1_ns - tok.t0_ns
        })
    }

    pub fn end_collective(tok: SpanToken, cat: Cat, bytes: u64) {
        if !tok.active {
            return;
        }
        let ns = close(tok, SpanKind::of_cat(cat), NO_LABEL, bytes);
        RANK.with(|r| {
            let mut r = r.borrow_mut();
            let Some(st) = r.as_mut() else { return };
            match cat {
                Cat::AllGather => {
                    st.bump(Ctr::AgBytes, bytes);
                    st.bump(Ctr::AgCalls, 1);
                    st.bump(Ctr::AgNs, ns);
                }
                Cat::AllReduce => {
                    st.bump(Ctr::ArBytes, bytes);
                    st.bump(Ctr::ArCalls, 1);
                    st.bump(Ctr::ArNs, ns);
                }
                Cat::ReduceScatter => {
                    st.bump(Ctr::RscBytes, bytes);
                    st.bump(Ctr::RscCalls, 1);
                    st.bump(Ctr::RscNs, ns);
                }
                _ => st.bump(Ctr::BarrierCalls, 1),
            }
        });
    }

    pub fn end_stage(tok: SpanToken, name: &str) {
        if !tok.active {
            return;
        }
        let label = RANK.with(|r| {
            r.borrow_mut().as_mut().map_or(NO_LABEL, |st| st.intern(name))
        });
        close(tok, SpanKind::Stage, label, 0);
    }

    pub fn end_iter(tok: SpanToken, iter: u64) {
        if !tok.active {
            return;
        }
        close(tok, SpanKind::NmfIter, NO_LABEL, iter);
        count(Ctr::NmfIters, 1);
    }

    pub fn end_ckpt(tok: SpanToken, bytes: u64) {
        if !tok.active {
            return;
        }
        let ns = close(tok, SpanKind::Checkpoint, NO_LABEL, bytes);
        count(Ctr::CkptCommits, 1);
        count(Ctr::CkptNs, ns);
    }

    pub fn end_store_write(tok: SpanToken, bytes: u64, spill_bytes: u64) {
        if !tok.active {
            return;
        }
        close(tok, SpanKind::StoreWrite, NO_LABEL, bytes);
        count(Ctr::StoreWriteBytes, bytes);
        count(Ctr::StoreSpillBytes, spill_bytes);
    }

    pub fn end_store_read(tok: SpanToken, bytes: u64) {
        if !tok.active {
            return;
        }
        close(tok, SpanKind::StoreRead, NO_LABEL, bytes);
        count(Ctr::SpillReadBytes, bytes);
    }

    pub fn end_query_batch(
        tok: SpanToken,
        queries: u64,
        modes_reused: u64,
        modes_computed: u64,
    ) {
        if !tok.active {
            return;
        }
        close(tok, SpanKind::QueryBatch, NO_LABEL, queries);
        count(Ctr::QueryBatches, 1);
        count(Ctr::Queries, queries);
        count(Ctr::PrefixModesReused, modes_reused);
        count(Ctr::PrefixModesComputed, modes_computed);
    }

    pub fn count(c: Ctr, delta: u64) {
        RANK.with(|r| {
            if let Some(st) = r.borrow_mut().as_mut() {
                st.bump(c, delta);
            }
        });
    }
}

#[cfg(not(feature = "trace"))]
mod plumbing {
    use super::TraceCollector;
    use crate::obs::metrics::Ctr;
    use crate::util::timer::Cat;
    use std::sync::Arc;

    /// No-op without the `trace` feature (nothing is ever recorded).
    pub fn arm(_collector: &Arc<TraceCollector>) {}

    pub fn disarm() {}

    pub fn armed() -> Option<Arc<TraceCollector>> {
        None
    }

    #[inline(always)]
    pub fn enter_rank(_collector: Option<Arc<TraceCollector>>, _rank: usize) {}

    #[inline(always)]
    pub fn exit_rank() {}

    /// Zero-sized in default-off builds.
    #[derive(Debug)]
    pub struct SpanToken;

    #[inline(always)]
    pub fn span_begin() -> SpanToken {
        SpanToken
    }

    #[inline(always)]
    pub fn end_collective(_tok: SpanToken, _cat: Cat, _bytes: u64) {}

    #[inline(always)]
    pub fn end_stage(_tok: SpanToken, _name: &str) {}

    #[inline(always)]
    pub fn end_iter(_tok: SpanToken, _iter: u64) {}

    #[inline(always)]
    pub fn end_ckpt(_tok: SpanToken, _bytes: u64) {}

    #[inline(always)]
    pub fn end_store_write(_tok: SpanToken, _bytes: u64, _spill_bytes: u64) {}

    #[inline(always)]
    pub fn end_store_read(_tok: SpanToken, _bytes: u64) {}

    #[inline(always)]
    pub fn end_query_batch(
        _tok: SpanToken,
        _queries: u64,
        _modes_reused: u64,
        _modes_computed: u64,
    ) {
    }

    /// The counter hook: literally empty in trace-off builds.
    #[inline(always)]
    pub fn count(_c: Ctr, _delta: u64) {}
}

pub use plumbing::{arm, armed, disarm, SpanToken};
pub(crate) use plumbing::{
    count, end_ckpt, end_collective, end_iter, end_query_batch, end_stage,
    end_store_read, end_store_write, enter_rank, exit_rank, span_begin,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_report_exports_clean_trace() {
        let collector = TraceCollector::new(TraceConfig::default());
        let report = collector.take_report();
        assert!(report.ranks.is_empty());
        let v = report.chrome_trace_json();
        assert_eq!(v.get("traceEvents").as_arr().unwrap().len(), 0);
        assert_eq!(
            v.get("otherData").get("format").as_str(),
            Some("dntt-trace-v1")
        );
    }

    #[test]
    fn report_orders_and_aggregates_ranks() {
        let collector = TraceCollector::new(TraceConfig { ring_capacity: 4 });
        let mut ctrs_a = [0u64; NUM_CTRS];
        ctrs_a[Ctr::AgBytes as usize] = 100;
        let mut ctrs_b = [0u64; NUM_CTRS];
        ctrs_b[Ctr::AgBytes as usize] = 30;
        // Two attempts of rank 1 around one of rank 0, merged unsorted.
        collector.merge(RankTrace {
            rank: 1,
            events: vec![Event {
                kind: SpanKind::AllGather,
                label: NO_LABEL,
                arg: 100,
                t0_ns: 10,
                t1_ns: 20,
            }],
            names: Vec::new(),
            dropped: 2,
            open_spans: 0,
            counters: ctrs_a,
        });
        collector.merge(RankTrace {
            rank: 0,
            events: Vec::new(),
            names: vec!["tt.stage0".into()],
            dropped: 0,
            open_spans: 1,
            counters: [0; NUM_CTRS],
        });
        collector.merge(RankTrace {
            rank: 1,
            events: Vec::new(),
            names: Vec::new(),
            dropped: 0,
            open_spans: 0,
            counters: ctrs_b,
        });
        let report = collector.take_report();
        assert_eq!(report.rank_ids(), vec![0, 1]);
        assert_eq!(report.total(Ctr::AgBytes), 130);
        assert_eq!(report.events_total(), 1);
        assert_eq!(report.dropped_total(), 2);
        assert_eq!(report.open_spans_total(), 1);
        let per_rank = report.per_rank_counters();
        assert_eq!(per_rank.len(), 2);
        assert_eq!(per_rank[1].0, 1);
        assert_eq!(per_rank[1].1[Ctr::AgBytes as usize], 130);
        // Draining is destructive: a second take sees nothing.
        assert!(collector.take_report().ranks.is_empty());
    }

    #[test]
    fn chrome_export_is_parseable_and_complete() {
        let collector = TraceCollector::new(TraceConfig::default());
        collector.merge(RankTrace {
            rank: 3,
            events: vec![
                Event {
                    kind: SpanKind::Stage,
                    label: 0,
                    arg: 0,
                    t0_ns: 1_000,
                    t1_ns: 9_000,
                },
                Event {
                    kind: SpanKind::AllReduce,
                    label: NO_LABEL,
                    arg: 64,
                    t0_ns: 2_000,
                    t1_ns: 3_000,
                },
            ],
            names: vec!["tt.stage0".into()],
            dropped: 0,
            open_spans: 0,
            counters: [0; NUM_CTRS],
        });
        let text = collector.take_report().chrome_trace_json().to_pretty();
        let v = Json::parse(&text).expect("trace JSON parses");
        let events = v.get("traceEvents").as_arr().unwrap();
        // 1 thread_name metadata + 2 spans.
        assert_eq!(events.len(), 3);
        let stage = &events[1];
        assert_eq!(stage.get("ph").as_str(), Some("X"));
        assert_eq!(stage.get("name").as_str(), Some("tt.stage0"));
        assert_eq!(stage.get("tid").as_usize(), Some(3));
        assert_eq!(stage.get("ts").as_f64(), Some(1.0));
        assert_eq!(stage.get("dur").as_f64(), Some(8.0));
        assert_eq!(events[2].get("cat").as_str(), Some("all_reduce"));
        assert_eq!(v.get("otherData").get("open_spans").as_usize(), Some(0));
    }

    #[cfg(feature = "trace")]
    #[test]
    fn rank_hooks_record_spans_counters_and_overflow() {
        let collector = TraceCollector::new(TraceConfig { ring_capacity: 2 });
        enter_rank(Some(Arc::clone(&collector)), 5);
        let t = span_begin();
        end_collective(t, Cat::AllGather, 80);
        let t = span_begin();
        end_stage(t, "tt.stage0");
        // Ring is full: the third span is dropped but still counted.
        let t = span_begin();
        end_collective(t, Cat::AllReduce, 8);
        count(Ctr::GemmFlops, 1_000);
        exit_rank();
        let report = collector.take_report();
        assert_eq!(report.ranks.len(), 1);
        let tr = &report.ranks[0];
        assert_eq!(tr.rank, 5);
        assert_eq!(tr.events.len(), 2);
        assert_eq!(tr.dropped, 1);
        assert_eq!(tr.open_spans, 0);
        assert_eq!(tr.names, vec!["tt.stage0".to_string()]);
        assert_eq!(tr.counters[Ctr::AgBytes as usize], 80);
        assert_eq!(tr.counters[Ctr::AgCalls as usize], 1);
        assert_eq!(tr.counters[Ctr::ArBytes as usize], 8);
        assert_eq!(tr.counters[Ctr::GemmFlops as usize], 1_000);
        assert!(tr.counters[Ctr::AgNs as usize] > 0);
        // Not entered: hooks are inert.
        let t = span_begin();
        end_collective(t, Cat::AllGather, 999);
        assert!(collector.take_report().ranks.is_empty());
    }

    #[test]
    fn unentered_hooks_are_inert_and_armed_scopes_to_thread() {
        let collector = TraceCollector::new(TraceConfig::default());
        assert!(armed().is_none());
        arm(&collector);
        if TRACE_ENABLED {
            assert!(armed().is_some());
        } else {
            assert!(armed().is_none());
        }
        disarm();
        assert!(armed().is_none());
        // Hook calls on a non-rank thread never panic or record.
        let t = span_begin();
        end_iter(t, 1);
        count(Ctr::NmfIters, 1);
        assert!(collector.take_report().ranks.is_empty());
    }
}
