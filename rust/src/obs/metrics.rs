//! Typed metric counters: the fixed vocabulary of the `dntt-metrics-v1`
//! envelope.
//!
//! Every rank accumulates one flat `[u64; NUM_CTRS]` array (see
//! [`crate::obs::RankTrace::counters`]); the coordinator sums them into
//! job totals after [`crate::coordinator::run_job`]. Counters come in two
//! flavours:
//!
//! * **Deterministic** ([`Ctr::is_deterministic`] is `true`): bytes,
//!   calls, flops, hits. These are pure functions of the job
//!   configuration — the same seed yields bitwise-identical tallies on
//!   every rerun, which `tests/obs_neutrality.rs` asserts.
//! * **Timing** (`*_ns` counters): wall-clock nanoseconds, reproducible
//!   only statistically. Excluded from determinism checks.
//!
//! The numeric discriminants are an internal array layout, not a wire
//! format; the JSON envelope keys counters by [`Ctr::name`].

use crate::util::json::Json;

/// One typed counter slot.
///
/// Byte counters measure logical payload (`f64`s moved × 8) unless noted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum Ctr {
    /// Bytes gathered by `all_gather_varied` (sum over calls of the full
    /// gathered output).
    AgBytes = 0,
    /// All-gather collective invocations (including object gathers,
    /// which move no accountable bytes).
    AgCalls,
    /// Nanoseconds inside all-gather collectives.
    AgNs,
    /// Bytes reduced by `all_reduce_sum` / `all_reduce_scalar`.
    ArBytes,
    /// All-reduce collective invocations.
    ArCalls,
    /// Nanoseconds inside all-reduce collectives.
    ArNs,
    /// Bytes scattered by `reduce_scatter_uneven` (per-rank input size).
    RscBytes,
    /// Reduce-scatter collective invocations.
    RscCalls,
    /// Nanoseconds inside reduce-scatter collectives.
    RscNs,
    /// Barrier invocations (no payload).
    BarrierCalls,
    /// Logical bytes published into the chunk store.
    StoreWriteBytes,
    /// Bytes physically written to spill files (0 in memory mode).
    StoreSpillBytes,
    /// Logical bytes copied out of store views (`read_into`).
    StoreReadBytes,
    /// Bytes physically read back from spill files.
    SpillReadBytes,
    /// Dense floating-point operations (Gram + GEMM paths; one
    /// multiply-add counts as two flops).
    GemmFlops,
    /// Sparse floating-point operations (SpMM paths; 2 × nnz × r per
    /// product).
    SpmmFlops,
    /// Rows dropped by zero-row pruning before NMF.
    PruneRowsDropped,
    /// Columns dropped by zero-column pruning before NMF.
    PruneColsDropped,
    /// Durable checkpoint commits (stage or node granularity).
    CkptCommits,
    /// Nanoseconds inside checkpoint commits (write + manifest + fsync).
    CkptNs,
    /// NMF inner iterations executed (all stages, all loops).
    NmfIters,
    /// Serve-side batched query calls.
    QueryBatches,
    /// Individual point queries answered by batched serve calls.
    Queries,
    /// TT/HT modes whose partial contractions were reused from the
    /// prefix cache across consecutive sorted queries.
    PrefixModesReused,
    /// TT/HT modes recomputed because the query prefix diverged.
    PrefixModesComputed,
    /// Flops executed on the scalar kernel path (subset of
    /// `GemmFlops + SpmmFlops`, split by the path that actually ran so
    /// the trace shows which microkernel served the job).
    FlopsScalar,
    /// Flops executed on the AVX2 kernel path.
    FlopsAvx2,
    /// Flops executed on the AVX-512 kernel path (AVX2 tile on this
    /// toolchain — see `runtime::kernel`).
    FlopsAvx512,
    /// Flops executed on the NEON kernel path.
    FlopsNeon,
    /// Bytes served out of mmap-backed view chunks (out-of-core mode;
    /// counts the logical read like `StoreReadBytes`, but the pages are
    /// kernel-cached rather than heap-resident).
    StoreMmapBytes,
    /// Budgeted reshape batches executed by `dist_reshape_x` (1 per call
    /// when no memory budget is set; > calls means batching engaged).
    ReshapeBatches,
}

/// Number of counter slots (length of the per-rank array).
pub const NUM_CTRS: usize = Ctr::ReshapeBatches as usize + 1;

/// Every counter, in array-layout order.
pub const ALL_CTRS: [Ctr; NUM_CTRS] = [
    Ctr::AgBytes,
    Ctr::AgCalls,
    Ctr::AgNs,
    Ctr::ArBytes,
    Ctr::ArCalls,
    Ctr::ArNs,
    Ctr::RscBytes,
    Ctr::RscCalls,
    Ctr::RscNs,
    Ctr::BarrierCalls,
    Ctr::StoreWriteBytes,
    Ctr::StoreSpillBytes,
    Ctr::StoreReadBytes,
    Ctr::SpillReadBytes,
    Ctr::GemmFlops,
    Ctr::SpmmFlops,
    Ctr::PruneRowsDropped,
    Ctr::PruneColsDropped,
    Ctr::CkptCommits,
    Ctr::CkptNs,
    Ctr::NmfIters,
    Ctr::QueryBatches,
    Ctr::Queries,
    Ctr::PrefixModesReused,
    Ctr::PrefixModesComputed,
    Ctr::FlopsScalar,
    Ctr::FlopsAvx2,
    Ctr::FlopsAvx512,
    Ctr::FlopsNeon,
    Ctr::StoreMmapBytes,
    Ctr::ReshapeBatches,
];

impl Ctr {
    /// Stable snake_case key used in the `dntt-metrics-v1` envelope.
    pub fn name(self) -> &'static str {
        match self {
            Ctr::AgBytes => "ag_bytes",
            Ctr::AgCalls => "ag_calls",
            Ctr::AgNs => "ag_ns",
            Ctr::ArBytes => "ar_bytes",
            Ctr::ArCalls => "ar_calls",
            Ctr::ArNs => "ar_ns",
            Ctr::RscBytes => "rsc_bytes",
            Ctr::RscCalls => "rsc_calls",
            Ctr::RscNs => "rsc_ns",
            Ctr::BarrierCalls => "barrier_calls",
            Ctr::StoreWriteBytes => "store_write_bytes",
            Ctr::StoreSpillBytes => "store_spill_bytes",
            Ctr::StoreReadBytes => "store_read_bytes",
            Ctr::SpillReadBytes => "spill_read_bytes",
            Ctr::GemmFlops => "gemm_flops",
            Ctr::SpmmFlops => "spmm_flops",
            Ctr::PruneRowsDropped => "prune_rows_dropped",
            Ctr::PruneColsDropped => "prune_cols_dropped",
            Ctr::CkptCommits => "ckpt_commits",
            Ctr::CkptNs => "ckpt_ns",
            Ctr::NmfIters => "nmf_iters",
            Ctr::QueryBatches => "query_batches",
            Ctr::Queries => "queries",
            Ctr::PrefixModesReused => "prefix_modes_reused",
            Ctr::PrefixModesComputed => "prefix_modes_computed",
            Ctr::FlopsScalar => "flops_scalar",
            Ctr::FlopsAvx2 => "flops_avx2",
            Ctr::FlopsAvx512 => "flops_avx512",
            Ctr::FlopsNeon => "flops_neon",
            Ctr::StoreMmapBytes => "store_mmap_bytes",
            Ctr::ReshapeBatches => "reshape_batches",
        }
    }

    /// `true` for counters that are a pure function of the job config
    /// (bytes/calls/flops/hits); `false` for wall-clock `*_ns` counters.
    /// The per-path flop counters are deterministic for a fixed host and
    /// `DNTT_KERNEL` setting (the path is resolved once per process).
    pub fn is_deterministic(self) -> bool {
        !matches!(self, Ctr::AgNs | Ctr::ArNs | Ctr::RscNs | Ctr::CkptNs)
    }
}

/// The per-path flop counter for a kernel path (see
/// [`crate::linalg::simd::KernelPath`]).
pub fn path_ctr(path: crate::linalg::simd::KernelPath) -> Ctr {
    use crate::linalg::simd::KernelPath;
    match path {
        KernelPath::Scalar => Ctr::FlopsScalar,
        KernelPath::Avx2 => Ctr::FlopsAvx2,
        KernelPath::Avx512 => Ctr::FlopsAvx512,
        KernelPath::Neon => Ctr::FlopsNeon,
    }
}

/// Serialize one counter array as a JSON object keyed by [`Ctr::name`].
/// Zero counters are kept so envelope consumers see a fixed schema.
pub fn counters_json(counters: &[u64; NUM_CTRS]) -> Json {
    Json::obj(
        ALL_CTRS
            .iter()
            .map(|&c| (c.name(), Json::Num(counters[c as usize] as f64)))
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn discriminants_are_dense_and_ordered() {
        for (i, c) in ALL_CTRS.iter().enumerate() {
            assert_eq!(*c as usize, i, "ALL_CTRS out of order at {i}");
        }
        assert_eq!(ALL_CTRS.len(), NUM_CTRS);
    }

    #[test]
    fn names_are_unique() {
        let mut names: Vec<&str> = ALL_CTRS.iter().map(|c| c.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), NUM_CTRS);
    }

    #[test]
    fn ns_counters_are_nondeterministic_only() {
        for c in ALL_CTRS {
            assert_eq!(
                c.is_deterministic(),
                !c.name().ends_with("_ns"),
                "{} determinism flag disagrees with its name",
                c.name()
            );
        }
    }

    #[test]
    fn path_ctr_maps_every_path_to_a_distinct_counter() {
        use crate::linalg::simd::KernelPath;
        let mut ctrs: Vec<usize> =
            KernelPath::ALL.into_iter().map(|p| path_ctr(p) as usize).collect();
        ctrs.sort_unstable();
        ctrs.dedup();
        assert_eq!(ctrs.len(), KernelPath::ALL.len());
        assert_eq!(path_ctr(KernelPath::Scalar), Ctr::FlopsScalar);
    }

    #[test]
    fn counters_json_has_full_schema() {
        let v = counters_json(&[0; NUM_CTRS]);
        assert_eq!(v.as_obj().unwrap().len(), NUM_CTRS);
        assert_eq!(v.get("ag_bytes").as_usize(), Some(0));
    }
}
