//! Crate-wide observability: per-rank tracing, typed metric counters,
//! and cost-model validation data.
//!
//! The paper's scalability story (§IV-B, Figs 5–7) is a per-category
//! cost breakdown; this module makes that breakdown *inspectable* at
//! per-rank, per-stage, per-collective granularity without perturbing
//! the computation it measures. Three layers:
//!
//! 1. **Tracing** ([`TraceCollector`], [`RankTrace`], [`Event`]): every
//!    rank thread owns a fixed-capacity event ring recording closed
//!    spans for stages, NMF iterations, collectives, chunk-store
//!    traffic, checkpoint commits, and serve-side query batches. Rings
//!    merge into an [`ObsReport`] after the job; `--trace-out` exports
//!    Chrome trace-event JSON loadable in Perfetto, one timeline per
//!    rank.
//! 2. **Metrics** ([`Ctr`]): typed counters — bytes per collective,
//!    store read/write/spill bytes, GEMM/SpMM flop tallies, prune hits,
//!    checkpoint commit latencies, prefix-cache hit rates — aggregated
//!    into the versioned `dntt-metrics-v1` envelope (`--metrics-out`,
//!    built by [`crate::coordinator::JobReport::metrics_json`]).
//! 3. **Model validation**: the envelope and report tables compare
//!    measured collective time/bytes against the α-β
//!    [`crate::dist::CostModel`]; byte residuals are zero by
//!    construction (the model prices measured message sizes), so drift
//!    shows up purely in time.
//!
//! # Arming and neutrality
//!
//! Like [`crate::dist::faults`], the plumbing is scoped through
//! thread-locals: [`arm`] installs a collector on the coordinator
//! thread, [`crate::dist::Comm::run`] hands it to every rank thread it
//! spawns, and unarmed runs skip all recording behind one branch per
//! hook. Instrumentation never touches factor data — armed and unarmed
//! runs produce bitwise-identical factors (`tests/obs_neutrality.rs`).
//! Building with `--no-default-features` removes the `trace` feature
//! and with it every hook body; [`TRACE_ENABLED`] reports which build
//! this is.

mod metrics;
mod trace;

pub use metrics::{counters_json, path_ctr, Ctr, ALL_CTRS, NUM_CTRS};
pub use trace::{
    arm, armed, disarm, Event, ObsReport, RankTrace, SpanKind, SpanToken,
    TraceCollector, TraceConfig, NO_LABEL, TRACE_ENABLED,
};
pub(crate) use trace::{
    count, end_ckpt, end_collective, end_iter, end_query_batch, end_stage,
    end_store_read, end_store_write, enter_rank, exit_rank, span_begin,
};
