//! Job reports: human tables + machine-readable JSON.

use super::job::JobConfig;
use crate::ttrain::TtOutput;
use crate::util::json::Json;
use crate::util::timer::{Breakdown, ALL_CATS};

/// Aggregated result of one decomposition job.
pub struct JobReport {
    pub label: String,
    pub dims: Vec<usize>,
    pub grid: Vec<usize>,
    pub ranks: Vec<usize>,
    pub compression: f64,
    pub rel_error: Option<f64>,
    pub wall_secs: f64,
    /// Critical-path measured breakdown (max over ranks).
    pub measured: Breakdown,
    /// α-β-modeled cluster breakdown (if a cost model was configured).
    pub modeled: Option<Breakdown>,
    pub pjrt_hits: u64,
    pub output: TtOutput,
}

impl JobReport {
    pub fn new(
        job: &JobConfig,
        output: TtOutput,
        wall_secs: f64,
        rel_error: Option<f64>,
        modeled: Option<Breakdown>,
        pjrt_hits: u64,
    ) -> Self {
        JobReport {
            label: job.input.label(),
            dims: job.input.dims(),
            grid: job.grid.dims().to_vec(),
            ranks: output.tt.ranks().to_vec(),
            compression: output.tt.compression_ratio(),
            rel_error,
            wall_secs,
            measured: output.breakdown.clone(),
            modeled,
            pjrt_hits,
            output,
        }
    }

    /// Multi-line human summary (the tables printed by the CLI).
    pub fn summary(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "input {} | grid {:?} ({} ranks)\n",
            self.label,
            self.grid,
            self.grid.iter().product::<usize>()
        ));
        s.push_str(&format!("TT ranks      : {:?}\n", self.ranks));
        s.push_str(&format!("compression   : {:.4}x\n", self.compression));
        if let Some(e) = self.rel_error {
            s.push_str(&format!("rel error     : {:.6}\n", e));
        }
        s.push_str(&format!("wall time     : {:.3}s\n", self.wall_secs));
        if self.pjrt_hits > 0 {
            s.push_str(&format!("pjrt op hits  : {}\n", self.pjrt_hits));
        }
        s.push_str("\nmeasured breakdown (critical path over ranks):\n");
        s.push_str(&self.measured.table());
        if let Some(m) = &self.modeled {
            s.push_str("\nmodeled cluster breakdown (α-β model):\n");
            s.push_str(&m.table());
        }
        // Per-stage table.
        s.push_str("\nstage   m        n          rank  svd_eps    nmf_relerr  restarts\n");
        for st in &self.output.stages {
            s.push_str(&format!(
                "{:<7} {:<8} {:<10} {:<5} {:<10.3e} {:<11.4e} {}\n",
                st.mode, st.m, st.n, st.rank, st.svd_eps, st.nmf.rel_err, st.nmf.restarts
            ));
        }
        s
    }

    /// Machine-readable record (one row of a bench series).
    pub fn to_json(&self) -> Json {
        let breakdown_json = |b: &Breakdown| {
            Json::Obj(
                ALL_CATS
                    .iter()
                    .filter(|&&c| b.calls(c) > 0 || b.secs(c) > 0.0)
                    .map(|&c| {
                        (
                            c.name().to_string(),
                            Json::obj(vec![
                                ("secs", Json::Num(b.secs(c))),
                                ("calls", Json::Num(b.calls(c) as f64)),
                                ("bytes", Json::Num(b.bytes(c) as f64)),
                            ]),
                        )
                    })
                    .collect(),
            )
        };
        let mut fields = vec![
            ("label", Json::Str(self.label.clone())),
            ("dims", Json::arr_usize(&self.dims)),
            ("grid", Json::arr_usize(&self.grid)),
            ("ranks", Json::arr_usize(&self.ranks)),
            ("compression", Json::Num(self.compression)),
            ("wall_secs", Json::Num(self.wall_secs)),
            ("measured", breakdown_json(&self.measured)),
            ("pjrt_hits", Json::Num(self.pjrt_hits as f64)),
        ];
        if let Some(e) = self.rel_error {
            fields.push(("rel_error", Json::Num(e)));
        }
        if let Some(m) = &self.modeled {
            fields.push(("modeled", breakdown_json(m)));
            fields.push(("modeled_total", Json::Num(m.total_secs())));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_job, InputSpec, JobConfig};
    use crate::dist::ProcGrid;
    use crate::nmf::NmfConfig;
    use crate::ttrain::{SyntheticTt, TtConfig};

    #[test]
    fn summary_and_json_render() {
        let job = JobConfig {
            tt: TtConfig {
                eps: 1e-6,
                nmf: NmfConfig { max_iters: 20, ..Default::default() },
                ..Default::default()
            },
            ..JobConfig::new(
                InputSpec::Synthetic(SyntheticTt::new(vec![4, 4, 4], vec![2, 2], 5)),
                ProcGrid::new(vec![1, 1, 1]).unwrap(),
            )
        };
        let rep = run_job(&job).unwrap();
        let s = rep.summary();
        assert!(s.contains("TT ranks"));
        assert!(s.contains("compression"));
        let j = rep.to_json();
        assert!(j.get("compression").as_f64().unwrap() > 0.0);
        assert!(j.get("measured").as_obj().is_some());
        // JSON roundtrips.
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }
}
