//! Job reports: human tables + machine-readable JSON, for both the TT
//! and the HT decomposition outputs.

use super::job::{Decomposition, JobConfig};
use crate::ht::HtOutput;
use crate::obs::ObsReport;
use crate::tensor::DenseTensor;
use crate::ttrain::TtOutput;
use crate::util::json::Json;
use crate::util::timer::{Breakdown, Cat, ALL_CATS};

/// The decomposition a job produced, tagged by network.
pub enum DecompOutput {
    Tt(TtOutput),
    Ht(HtOutput),
}

impl DecompOutput {
    /// The TT output, when the job ran a tensor train.
    pub fn tt(&self) -> Option<&TtOutput> {
        match self {
            DecompOutput::Tt(o) => Some(o),
            DecompOutput::Ht(_) => None,
        }
    }

    /// The HT output, when the job ran a hierarchical Tucker.
    pub fn ht(&self) -> Option<&HtOutput> {
        match self {
            DecompOutput::Tt(_) => None,
            DecompOutput::Ht(o) => Some(o),
        }
    }

    pub fn decomp(&self) -> Decomposition {
        match self {
            DecompOutput::Tt(_) => Decomposition::Tt,
            DecompOutput::Ht(_) => Decomposition::Ht,
        }
    }

    /// Rank chain: TT ranks `r_0..r_d` (both ends 1) or HT parent-edge
    /// ranks in BFS node order (first entry is the root's trivial 1).
    pub fn ranks(&self) -> Vec<usize> {
        match self {
            DecompOutput::Tt(o) => o.tt.ranks().to_vec(),
            DecompOutput::Ht(o) => o.ht.ranks().to_vec(),
        }
    }

    pub fn compression(&self) -> f64 {
        match self {
            DecompOutput::Tt(o) => o.tt.compression_ratio(),
            DecompOutput::Ht(o) => o.ht.compression_ratio(),
        }
    }

    /// Compression ratio against an explicit input storage size in
    /// elements (sparse inputs: the nnz, not the dense bounding box).
    pub fn compression_vs(&self, input_elems: f64) -> f64 {
        match self {
            DecompOutput::Tt(o) => o.tt.compression_ratio_vs(input_elems),
            DecompOutput::Ht(o) => o.ht.compression_ratio_vs(input_elems),
        }
    }

    /// Clone the assembled network into a servable
    /// [`Artifact`](crate::tensor::io::Artifact) (the `--out` payload).
    pub fn artifact(&self) -> crate::tensor::io::Artifact {
        match self {
            DecompOutput::Tt(o) => crate::tensor::io::Artifact::Tt(o.tt.clone()),
            DecompOutput::Ht(o) => crate::tensor::io::Artifact::Ht(o.ht.clone()),
        }
    }

    pub fn is_nonneg(&self) -> bool {
        match self {
            DecompOutput::Tt(o) => o.tt.is_nonneg(),
            DecompOutput::Ht(o) => o.ht.is_nonneg(),
        }
    }

    /// Critical-path measured breakdown.
    pub fn breakdown(&self) -> &Breakdown {
        match self {
            DecompOutput::Tt(o) => &o.breakdown,
            DecompOutput::Ht(o) => &o.breakdown,
        }
    }

    /// Relative reconstruction error against a reference tensor.
    pub fn rel_error(&self, reference: &DenseTensor<f64>) -> f64 {
        match self {
            DecompOutput::Tt(o) => o.tt.rel_error(reference),
            DecompOutput::Ht(o) => o.ht.rel_error(reference),
        }
    }
}

/// One per-collective row of the α-β model validation (Fig-5-style):
/// what the ranks measured next to what [`crate::dist::CostModel`]
/// predicts for the same call/byte counts.
#[derive(Clone, Copy, Debug)]
pub struct ModelResidual {
    pub cat: Cat,
    pub calls: u64,
    pub measured_bytes: u64,
    pub modeled_bytes: u64,
    pub measured_secs: f64,
    pub modeled_secs: f64,
}

impl ModelResidual {
    /// Modeled minus measured payload bytes. Zero by construction: the
    /// model re-prices *time* but carries the measured byte counters
    /// over verbatim, so any non-zero value flags an accounting bug.
    pub fn byte_residual(&self) -> i64 {
        self.modeled_bytes as i64 - self.measured_bytes as i64
    }

    /// Modeled minus measured seconds — the model drift (positive when
    /// the cluster model prices the collective above the shared-memory
    /// measurement, the expected direction).
    pub fn time_residual(&self) -> f64 {
        self.modeled_secs - self.measured_secs
    }
}

/// Aggregated result of one decomposition job.
pub struct JobReport {
    pub label: String,
    pub decomp: Decomposition,
    pub dims: Vec<usize>,
    pub grid: Vec<usize>,
    /// See [`DecompOutput::ranks`].
    pub ranks: Vec<usize>,
    pub compression: f64,
    pub rel_error: Option<f64>,
    pub wall_secs: f64,
    /// Critical-path measured breakdown (max over ranks).
    pub measured: Breakdown,
    /// α-β-modeled cluster breakdown (if a cost model was configured).
    pub modeled: Option<Breakdown>,
    pub pjrt_hits: u64,
    /// Merged per-rank traces and counters ([`crate::obs`]), when the
    /// job was configured with [`JobConfig::trace`].
    pub obs: Option<ObsReport>,
    /// The job's [`JobConfig::fingerprint`], when the coordinator
    /// computed it (checkpointed or server-submitted jobs) — the result
    /// cache key, surfaced so operators can correlate reports, cache
    /// entries, and metrics envelopes.
    pub fingerprint: Option<u64>,
    /// High-water mark of chunk-store resident bytes across all attempts
    /// ([`crate::dist::SharedStore`] `MemStats`) — the out-of-core
    /// acceptance signal. Set by the coordinator after construction.
    pub peak_resident_bytes: Option<u64>,
    /// The configured memory budget ([`JobConfig::budget`]), echoed so
    /// envelope consumers can check peak ≤ budget without the config.
    pub budget_bytes: Option<u64>,
    pub output: DecompOutput,
}

impl JobReport {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        job: &JobConfig,
        output: DecompOutput,
        wall_secs: f64,
        rel_error: Option<f64>,
        modeled: Option<Breakdown>,
        pjrt_hits: u64,
        obs: Option<ObsReport>,
    ) -> Self {
        JobReport {
            label: job.input.label(),
            decomp: output.decomp(),
            dims: job.input.dims(),
            grid: job.grid.dims().to_vec(),
            ranks: output.ranks(),
            // Honest ratio: sparse inputs are credited with their stored
            // nnz, dense inputs with the full element count.
            compression: output.compression_vs(job.input.storage_elems()),
            rel_error,
            wall_secs,
            measured: output.breakdown().clone(),
            modeled,
            pjrt_hits,
            obs,
            fingerprint: None,
            peak_resident_bytes: None,
            budget_bytes: None,
            output,
        }
    }

    /// Per-collective measured-vs-modeled rows. Empty without a cost
    /// model. Byte residuals are zero by construction (see
    /// [`ModelResidual::byte_residual`]); the time residuals are the
    /// Fig-5-style model-validation signal.
    pub fn model_residuals(&self) -> Vec<ModelResidual> {
        let Some(m) = &self.modeled else { return Vec::new() };
        ALL_CATS
            .iter()
            .filter(|&&c| {
                c.is_comm() && (self.measured.calls(c) > 0 || self.measured.bytes(c) > 0)
            })
            .map(|&c| ModelResidual {
                cat: c,
                calls: self.measured.calls(c),
                measured_bytes: self.measured.bytes(c),
                modeled_bytes: m.bytes(c),
                measured_secs: self.measured.secs(c),
                modeled_secs: m.secs(c),
            })
            .collect()
    }

    /// Multi-line human summary (the tables printed by the CLI).
    pub fn summary(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "input {} | decomp {} | grid {:?} ({} ranks)\n",
            self.label,
            self.decomp.name(),
            self.grid,
            self.grid.iter().product::<usize>()
        ));
        match self.decomp {
            Decomposition::Tt => s.push_str(&format!("TT ranks      : {:?}\n", self.ranks)),
            Decomposition::Ht => {
                s.push_str(&format!("HT edge ranks : {:?} (BFS node order)\n", self.ranks))
            }
        }
        s.push_str(&format!("compression   : {:.4}x\n", self.compression));
        if let Some(e) = self.rel_error {
            s.push_str(&format!("rel error     : {:.6}\n", e));
        }
        s.push_str(&format!("wall time     : {:.3}s\n", self.wall_secs));
        if let Some(peak) = self.peak_resident_bytes {
            match self.budget_bytes {
                Some(b) => s.push_str(&format!(
                    "peak resident : {:.2} MiB (budget {:.2} MiB)\n",
                    peak as f64 / (1 << 20) as f64,
                    b as f64 / (1 << 20) as f64,
                )),
                None if peak > 0 => s.push_str(&format!(
                    "peak resident : {:.2} MiB\n",
                    peak as f64 / (1 << 20) as f64
                )),
                None => {}
            }
        }
        if self.pjrt_hits > 0 {
            s.push_str(&format!("pjrt op hits  : {}\n", self.pjrt_hits));
        }
        s.push_str("\nmeasured breakdown (critical path over ranks):\n");
        s.push_str(&self.measured.table());
        if let Some(m) = &self.modeled {
            s.push_str("\nmodeled cluster breakdown (α-β model):\n");
            s.push_str(&m.table());
        }
        let residuals = self.model_residuals();
        if !residuals.is_empty() {
            s.push_str("\nα-β model validation (per collective; Δbytes is 0 by construction):\n");
            s.push_str("cat   calls    bytes         Δbytes  measured_s  modeled_s   drift_s\n");
            for r in &residuals {
                s.push_str(&format!(
                    "{:<5} {:<8} {:<13} {:<7} {:<11.4e} {:<11.4e} {:+.4e}\n",
                    r.cat.name(),
                    r.calls,
                    r.measured_bytes,
                    r.byte_residual(),
                    r.measured_secs,
                    r.modeled_secs,
                    r.time_residual(),
                ));
            }
        }
        if let Some(o) = &self.obs {
            s.push_str(&format!(
                "\ntrace: {} events on {} rank timeline(s), {} dropped, {} open\n",
                o.events_total(),
                o.rank_ids().len(),
                o.dropped_total(),
                o.open_spans_total(),
            ));
        }
        match &self.output {
            DecompOutput::Tt(out) => {
                s.push_str(
                    "\nstage   m        n          rank  svd_eps    nmf_relerr  restarts\n",
                );
                for st in &out.stages {
                    s.push_str(&format!(
                        "{:<7} {:<8} {:<10} {:<5} {:<10.3e} {:<11.4e} {}\n",
                        st.mode, st.m, st.n, st.rank, st.svd_eps, st.nmf.rel_err, st.nmf.restarts
                    ));
                }
            }
            DecompOutput::Ht(out) => {
                s.push_str(
                    "\nnode  modes   edge  m        n        rank  svd_eps    nmf_relerr  secs\n",
                );
                for st in &out.stages {
                    s.push_str(&format!(
                        "{:<5} [{},{})   {:<4} {:<8} {:<8} {:<5} {:<10.3e} {:<11.4e} {:.3}\n",
                        st.node,
                        st.modes.0,
                        st.modes.1,
                        if st.left { "L" } else { "R" },
                        st.m,
                        st.n,
                        st.rank,
                        st.svd_eps,
                        st.nmf.rel_err,
                        st.secs
                    ));
                }
            }
        }
        s
    }

    /// Machine-readable record (one row of a bench series).
    pub fn to_json(&self) -> Json {
        let breakdown_json = |b: &Breakdown| {
            Json::Obj(
                ALL_CATS
                    .iter()
                    .filter(|&&c| b.calls(c) > 0 || b.secs(c) > 0.0)
                    .map(|&c| {
                        (
                            c.name().to_string(),
                            Json::obj(vec![
                                ("secs", Json::Num(b.secs(c))),
                                ("calls", Json::Num(b.calls(c) as f64)),
                                ("bytes", Json::Num(b.bytes(c) as f64)),
                            ]),
                        )
                    })
                    .collect(),
            )
        };
        let stages = match &self.output {
            DecompOutput::Tt(out) => Json::Arr(
                out.stages
                    .iter()
                    .map(|st| {
                        let mut f = vec![
                            ("mode", Json::Num(st.mode as f64)),
                            ("m", Json::Num(st.m as f64)),
                            ("n", Json::Num(st.n as f64)),
                            ("rank", Json::Num(st.rank as f64)),
                            ("nmf_rel_err", Json::Num(st.nmf.rel_err)),
                            ("restarts", Json::Num(st.nmf.restarts as f64)),
                        ];
                        if st.svd_eps.is_finite() {
                            f.push(("svd_eps", Json::Num(st.svd_eps)));
                        }
                        Json::obj(f)
                    })
                    .collect(),
            ),
            DecompOutput::Ht(out) => Json::Arr(
                out.stages
                    .iter()
                    .map(|st| {
                        let mut f = vec![
                            ("node", Json::Num(st.node as f64)),
                            ("modes", Json::arr_usize(&[st.modes.0, st.modes.1])),
                            ("edge", Json::Str(if st.left { "L" } else { "R" }.into())),
                            ("m", Json::Num(st.m as f64)),
                            ("n", Json::Num(st.n as f64)),
                            ("rank", Json::Num(st.rank as f64)),
                            ("nmf_rel_err", Json::Num(st.nmf.rel_err)),
                            ("secs", Json::Num(st.secs)),
                        ];
                        if st.svd_eps.is_finite() {
                            f.push(("svd_eps", Json::Num(st.svd_eps)));
                        }
                        Json::obj(f)
                    })
                    .collect(),
            ),
        };
        let mut fields = vec![
            ("label", Json::Str(self.label.clone())),
            ("decomp", Json::Str(self.decomp.name().into())),
            ("dims", Json::arr_usize(&self.dims)),
            ("grid", Json::arr_usize(&self.grid)),
            ("ranks", Json::arr_usize(&self.ranks)),
            ("compression", Json::Num(self.compression)),
            ("wall_secs", Json::Num(self.wall_secs)),
            ("measured", breakdown_json(&self.measured)),
            ("stages", stages),
            ("pjrt_hits", Json::Num(self.pjrt_hits as f64)),
        ];
        if let Some(fp) = self.fingerprint {
            fields.push(("fingerprint", Json::Str(format!("{fp:016x}"))));
        }
        if let Some(e) = self.rel_error {
            fields.push(("rel_error", Json::Num(e)));
        }
        if let Some(m) = &self.modeled {
            fields.push(("modeled", breakdown_json(m)));
            fields.push(("modeled_total", Json::Num(m.total_secs())));
        }
        if let Some(mem) = self.memory_json() {
            fields.push(("memory", mem));
        }
        Json::obj(fields)
    }

    /// The `memory` section shared by [`JobReport::to_json`] and
    /// [`JobReport::metrics_json`]: present whenever the coordinator
    /// recorded a peak (always for jobs run through `run_job`), with the
    /// budget echoed when one was configured.
    fn memory_json(&self) -> Option<Json> {
        let peak = self.peak_resident_bytes?;
        let mut f = vec![("peak_resident_bytes", Json::Num(peak as f64))];
        if let Some(b) = self.budget_bytes {
            f.push(("budget_bytes", Json::Num(b as f64)));
        }
        Some(Json::obj(f))
    }

    /// The versioned `dntt-metrics-v1` envelope (the `--metrics-out`
    /// payload): job identity, wall time, per-stage convergence series,
    /// the per-collective α-β validation rows (byte residuals zero by
    /// construction, time residuals report the drift), and — when the
    /// job traced — the obs counter totals, per-rank counters, and ring
    /// statistics.
    pub fn metrics_json(&self) -> Json {
        let mut fields = vec![
            ("format", Json::Str("dntt-metrics-v1".into())),
            ("label", Json::Str(self.label.clone())),
            ("decomp", Json::Str(self.decomp.name().into())),
            ("dims", Json::arr_usize(&self.dims)),
            ("grid", Json::arr_usize(&self.grid)),
            ("ranks", Json::arr_usize(&self.ranks)),
            ("compression", Json::Num(self.compression)),
            ("wall_secs", Json::Num(self.wall_secs)),
        ];
        if let Some(fp) = self.fingerprint {
            fields.push(("fingerprint", Json::Str(format!("{fp:016x}"))));
        }
        if let Some(e) = self.rel_error {
            fields.push(("rel_error", Json::Num(e)));
        }
        let convergence = match &self.output {
            DecompOutput::Tt(out) => Json::Arr(
                out.stages
                    .iter()
                    .map(|st| {
                        Json::obj(vec![
                            ("stage", Json::Str(format!("tt.stage{}", st.mode))),
                            ("objectives", Json::arr_f64(&st.nmf.history)),
                        ])
                    })
                    .collect(),
            ),
            DecompOutput::Ht(out) => Json::Arr(
                out.stages
                    .iter()
                    .map(|st| {
                        let edge = if st.left { "a" } else { "b" };
                        Json::obj(vec![
                            ("stage", Json::Str(format!("ht.n{}.{edge}", st.node))),
                            ("objectives", Json::arr_f64(&st.nmf.history)),
                        ])
                    })
                    .collect(),
            ),
        };
        fields.push(("convergence", convergence));
        let collectives = Json::Arr(
            self.model_residuals()
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("cat", Json::Str(r.cat.name().into())),
                        ("calls", Json::Num(r.calls as f64)),
                        ("measured_bytes", Json::Num(r.measured_bytes as f64)),
                        ("modeled_bytes", Json::Num(r.modeled_bytes as f64)),
                        ("byte_residual", Json::Num(r.byte_residual() as f64)),
                        ("measured_secs", Json::Num(r.measured_secs)),
                        ("modeled_secs", Json::Num(r.modeled_secs)),
                        ("time_residual_secs", Json::Num(r.time_residual())),
                    ])
                })
                .collect(),
        );
        fields.push(("collectives", collectives));
        if let Some(mem) = self.memory_json() {
            fields.push(("memory", mem));
        }
        if let Some(o) = &self.obs {
            fields.push(("counters", o.counters_section_json()));
            fields.push((
                "trace",
                Json::obj(vec![
                    ("ring_capacity", Json::Num(o.ring_capacity as f64)),
                    ("events", Json::Num(o.events_total() as f64)),
                    ("dropped", Json::Num(o.dropped_total() as f64)),
                    ("open_spans", Json::Num(o.open_spans_total() as f64)),
                    ("rank_timelines", Json::Num(o.rank_ids().len() as f64)),
                ]),
            ));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_job, Decomposition, InputSpec, JobConfig};
    use crate::dist::ProcGrid;
    use crate::ht::HtConfig;
    use crate::nmf::NmfConfig;
    use crate::ttrain::{SyntheticTt, TtConfig};

    #[test]
    fn summary_and_json_render() {
        let job = JobConfig {
            tt: TtConfig {
                eps: 1e-6,
                nmf: NmfConfig { max_iters: 20, ..Default::default() },
                ..Default::default()
            },
            ..JobConfig::new(
                InputSpec::Synthetic(SyntheticTt::new(vec![4, 4, 4], vec![2, 2], 5)),
                ProcGrid::new(vec![1, 1, 1]).unwrap(),
            )
        };
        let rep = run_job(&job).unwrap();
        let s = rep.summary();
        assert!(s.contains("TT ranks"));
        assert!(s.contains("compression"));
        assert!(s.contains("decomp tt"));
        let j = rep.to_json();
        assert!(j.get("compression").as_f64().unwrap() > 0.0);
        assert!(j.get("measured").as_obj().is_some());
        // JSON roundtrips.
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn ht_summary_and_json_render() {
        let job = JobConfig {
            decomp: Decomposition::Ht,
            ht: HtConfig {
                eps: 1e-6,
                nmf: NmfConfig { max_iters: 20, ..Default::default() },
                ..Default::default()
            },
            ..JobConfig::new(
                InputSpec::Synthetic(SyntheticTt::new(vec![4, 4, 4], vec![2, 2], 5)),
                ProcGrid::new(vec![1, 1, 1]).unwrap(),
            )
        };
        let rep = run_job(&job).unwrap();
        let s = rep.summary();
        assert!(s.contains("HT edge ranks"));
        assert!(s.contains("decomp ht"));
        assert!(s.contains("node  modes"));
        let j = rep.to_json();
        assert_eq!(j.get("decomp").as_str().unwrap(), "ht");
        // Two stages per interior node, all serialized (NaN-free).
        assert_eq!(j.get("stages").as_arr().unwrap().len(), 4);
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }
}
