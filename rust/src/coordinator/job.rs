//! Job specification: what to decompose, on what (logical) cluster, with
//! which backend and algorithm.

use crate::data::{FaceConfig, VideoConfig};
use crate::dist::checkpoint::CheckpointPolicy;
use crate::dist::chunkstore::SpillMode;
use crate::dist::{CostModel, ProcGrid};
use crate::ht::HtConfig;
use crate::tensor::DenseTensor;
use crate::ttrain::{SyntheticSparse, SyntheticTt, TtConfig};
use std::path::PathBuf;
use std::sync::Arc;

/// Which tensor-network decomposition a job runs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Decomposition {
    /// Tensor train (Alg 2 of the paper) — the left-to-right sweep.
    #[default]
    Tt,
    /// Hierarchical Tucker — the level-by-level sweep down the balanced
    /// dimension tree (`crate::ht`).
    Ht,
}

impl Decomposition {
    pub fn name(self) -> &'static str {
        match self {
            Decomposition::Tt => "tt",
            Decomposition::Ht => "ht",
        }
    }
}

impl std::str::FromStr for Decomposition {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "tt" => Ok(Decomposition::Tt),
            "ht" => Ok(Decomposition::Ht),
            _ => Err(format!("unknown decomposition '{s}' (tt|ht)")),
        }
    }
}

/// Where the input tensor comes from.
#[derive(Clone)]
pub enum InputSpec {
    /// §IV-A synthetic TT tensor — blocks are generated per rank without
    /// ever materializing the full tensor (scales to out-of-core sizes).
    Synthetic(SyntheticTt),
    /// Synthetic **sparse** tensor with controllable density — blocks are
    /// generated per rank as sparse chunks; the dense tensor is never
    /// materialized on the distributed path.
    SyntheticSparse(SyntheticSparse),
    /// Synthetic Yale-B-like face tensor (materialized once, shared).
    Faces(FaceConfig),
    /// Synthetic high-speed video tensor.
    Video(VideoConfig),
    /// A caller-provided dense tensor.
    Dense(Arc<DenseTensor<f64>>),
    /// An on-disk `dntt-chunks-v1` chunk set ([`crate::tensor::ChunkSet`])
    /// — the out-of-core path. Blocks are adopted file-in-place by the
    /// chunk store; the full tensor is never materialized. Dims and
    /// content identity are captured at [`InputSpec::from_chunks`] time
    /// so fingerprinting needs no re-read.
    File {
        dir: PathBuf,
        dims: Vec<usize>,
        /// [`crate::tensor::ChunkSet::identity`] (FNV over manifest CRCs).
        identity: u64,
    },
}

impl InputSpec {
    /// Open a `dntt-chunks-v1` directory as a job input, validating the
    /// manifest and capturing its dims and content identity.
    pub fn from_chunks(dir: &std::path::Path) -> crate::error::Result<InputSpec> {
        let cs = crate::tensor::ChunkSet::open(dir)?;
        Ok(InputSpec::File {
            dir: dir.to_path_buf(),
            dims: cs.dims().to_vec(),
            identity: cs.identity(),
        })
    }

    pub fn dims(&self) -> Vec<usize> {
        match self {
            InputSpec::Synthetic(s) => s.dims.clone(),
            InputSpec::SyntheticSparse(s) => s.dims.clone(),
            InputSpec::Faces(c) => vec![c.height, c.width, c.illuminations, c.subjects],
            InputSpec::Video(c) => vec![c.height, c.width, c.channels, c.frames],
            InputSpec::Dense(t) => t.dims().to_vec(),
            InputSpec::File { dims, .. } => dims.clone(),
        }
    }

    /// Input *storage* size in elements: the dense element count, except
    /// for sparse inputs, which are credited with their nnz (exact when
    /// countable, expected otherwise) — the honest denominator-free basis
    /// for [`crate::coordinator::JobReport`]'s compression ratio.
    pub fn storage_elems(&self) -> f64 {
        match self {
            InputSpec::SyntheticSparse(s) => s.storage_nnz(),
            other => other.dims().iter().map(|&n| n as f64).product(),
        }
    }

    /// Materialize the full tensor when feasible (None for the synthetic
    /// inputs, which are generated blockwise).
    pub fn materialize(&self) -> Option<Arc<DenseTensor<f64>>> {
        match self {
            InputSpec::Synthetic(_) | InputSpec::SyntheticSparse(_) => None,
            InputSpec::Faces(c) => Some(Arc::new(crate::data::generate_faces(c))),
            InputSpec::Video(c) => Some(Arc::new(crate::data::generate_video(c))),
            InputSpec::Dense(t) => Some(t.clone()),
            // Out-of-core by definition; error checking reads chunks back
            // lazily instead.
            InputSpec::File { .. } => None,
        }
    }

    pub fn label(&self) -> String {
        match self {
            InputSpec::Synthetic(s) => format!("synthetic{:?}r{:?}", s.dims, s.ranks),
            InputSpec::SyntheticSparse(s) => format!("sparse{:?}d{}", s.dims, s.density),
            InputSpec::Faces(_) => "faces".into(),
            InputSpec::Video(_) => "video".into(),
            InputSpec::Dense(t) => format!("dense{:?}", t.dims()),
            InputSpec::File { dims, .. } => format!("file{dims:?}"),
        }
    }

    /// Full identity of the input *data* (unlike [`InputSpec::label`],
    /// which is a display string): generator seeds for the synthetic
    /// inputs, the complete config for faces/video, and a content hash
    /// for caller-provided tensors. Feeds
    /// [`JobConfig::fingerprint`] so two jobs over different tensors can
    /// never share a checkpoint config hash.
    fn identity(&self) -> String {
        match self {
            InputSpec::Synthetic(s) => format!("synthetic|{:?}|{:?}|{}", s.dims, s.ranks, s.seed),
            InputSpec::SyntheticSparse(s) => {
                format!("sparse|{:?}|{:016x}|{}", s.dims, s.density.to_bits(), s.seed)
            }
            InputSpec::Faces(c) => format!("faces|{c:?}"),
            InputSpec::Video(c) => format!("video|{c:?}"),
            InputSpec::Dense(t) => {
                // The tensor content itself is the identity.
                let h = fnv1a(t.as_slice().iter().flat_map(|x| x.to_le_bytes()));
                format!("dense|{:?}|{h:016x}", t.dims())
            }
            // Content-addressed via the manifest CRCs: the same chunk set
            // copied to another directory fingerprints identically.
            InputSpec::File { dims, identity, .. } => {
                format!("file|{dims:?}|{identity:016x}")
            }
        }
    }
}

/// FNV-1a 64-bit fold — shared by the input-identity and configuration
/// fingerprints so the two can never desynchronize.
fn fnv1a(bytes: impl Iterator<Item = u8>) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Which compute backend the ranks use.
#[derive(Clone, Debug, Default)]
pub enum BackendChoice {
    #[default]
    Native,
    /// PJRT over the artifact directory (native fallback per shape).
    Pjrt(PathBuf),
}

/// Whether [`crate::coordinator::run_job`] consults an existing
/// checkpoint and relaunches after a lost rank.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ResumeMode {
    /// Ignore existing checkpoints; a lost rank fails the job with
    /// [`crate::error::DnttError::RankLost`].
    #[default]
    Off,
    /// Validate + resume from the checkpoint directory's manifest when
    /// one exists, and relaunch the world from the last durable
    /// checkpoint when a rank is lost mid-run.
    Auto,
}

impl std::str::FromStr for ResumeMode {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "off" => Ok(ResumeMode::Off),
            "auto" => Ok(ResumeMode::Auto),
            _ => Err(format!("unknown resume mode '{s}' (off|auto)")),
        }
    }
}

/// A full decomposition job.
#[derive(Clone)]
pub struct JobConfig {
    pub input: InputSpec,
    pub grid: ProcGrid,
    /// Which network to decompose into (TT by default).
    pub decomp: Decomposition,
    /// TT parameters (used when `decomp == Decomposition::Tt`).
    pub tt: TtConfig,
    /// HT parameters (used when `decomp == Decomposition::Ht`).
    pub ht: HtConfig,
    pub backend: BackendChoice,
    pub spill: SpillMode,
    /// Model cluster timings with this α-β model (None = measured only).
    pub cost_model: Option<CostModel>,
    /// Compute the reconstruction error afterwards (requires materializing
    /// the tensor — skip for very large inputs).
    pub check_error: bool,
    /// Write `dntt-ckpt-v1` snapshots per this policy (None = no
    /// checkpointing).
    pub checkpoint: Option<CheckpointPolicy>,
    /// Resume/relaunch behavior (meaningful with `checkpoint` set).
    pub resume: ResumeMode,
    /// Leave spill chunk files on disk when the job's store is dropped
    /// (see [`crate::dist::SharedStore::set_keep_spill`]).
    pub keep_spill: bool,
    /// Record per-rank event traces and metric counters
    /// ([`crate::obs`]) into the report's `obs` field (None = no
    /// tracing). Excluded from [`JobConfig::fingerprint`]: tracing is
    /// bitwise-neutral (asserted by `tests/obs_neutrality.rs`), so a
    /// traced job may resume an untraced checkpoint and vice versa.
    pub trace: Option<crate::obs::TraceConfig>,
    /// GEMM/SpMM kernel policy (SIMD path selection, CLI `--kernel`;
    /// the `DNTT_KERNEL` env var overrides it at [`Self::kernel_cfg`]
    /// time). Excluded from [`JobConfig::fingerprint`]: every path is
    /// bitwise identical to scalar (`tests/kernel_conformance.rs`), so
    /// a job may resume a checkpoint written under any kernel policy,
    /// and JobServer cache entries are shared across policies.
    pub kernel: crate::linalg::KernelPolicy,
    /// Intra-rank worker threads for the packed GEMM / SpMM macro-panel
    /// loop (CLI `--threads-per-rank`, min 1). Excluded from the
    /// fingerprint for the same reason: threading partitions output
    /// panels without changing any per-element operation order.
    pub threads_per_rank: usize,
    /// Peak-resident memory budget in bytes for the chunk store (CLI
    /// `--budget-mb`, None = unbounded). Enables budgeted batch assembly
    /// in `dist_reshape_x` and — when `spill` is `SpillMode::Memory` —
    /// upgrades the store to mmap-backed spill so chunk bytes stay on
    /// disk. Excluded from [`JobConfig::fingerprint`]: the streamed path
    /// is bitwise-identical to the resident path
    /// (`tests/oo_core.rs`), so budgeted and unbudgeted runs share
    /// checkpoints and cache entries.
    pub budget: Option<u64>,
}

impl JobConfig {
    pub fn new(input: InputSpec, grid: ProcGrid) -> Self {
        JobConfig {
            input,
            grid,
            decomp: Decomposition::default(),
            tt: TtConfig::default(),
            ht: HtConfig::default(),
            backend: BackendChoice::Native,
            spill: SpillMode::Memory,
            cost_model: Some(CostModel::default()),
            check_error: true,
            checkpoint: None,
            resume: ResumeMode::Off,
            keep_spill: false,
            trace: None,
            kernel: crate::linalg::KernelPolicy::default(),
            threads_per_rank: 1,
            budget: None,
        }
    }

    /// The kernel selection handed to every rank: `DNTT_KERNEL` env
    /// override first, then the configured policy, resolved to a
    /// concrete available path (unavailable forced paths downgrade to
    /// scalar with a warning).
    pub fn kernel_cfg(&self) -> crate::linalg::KernelCfg {
        let policy = crate::linalg::KernelPolicy::from_env().unwrap_or(self.kernel);
        crate::linalg::KernelCfg::new(policy.resolve(), self.threads_per_rank)
    }

    /// Stable fingerprint of everything that determines the numerical
    /// trajectory (decomposition, dims, grid, input identity *including
    /// the data itself*, algorithm configuration, backend) — the
    /// `config_hash` a `dntt-ckpt-v1` manifest records, so a checkpoint
    /// is only ever resumed by the job that wrote it. Spill mode, cost
    /// model, error checking and the checkpoint/resume knobs themselves
    /// are excluded: they provably do not change the factors.
    pub fn fingerprint(&self) -> u64 {
        // FNV-1a over a canonical description; Debug formatting of f64
        // uses the shortest round-trip representation, so the hash is
        // exact in the configuration's floating-point fields.
        let canon = format!(
            "{}|{:?}|{:?}|{}|{:?}|{:?}|{:?}",
            self.decomp.name(),
            self.input.dims(),
            self.grid.dims(),
            self.input.identity(),
            self.tt,
            self.ht,
            self.backend,
        );
        fnv1a(canon.bytes())
    }
}
