//! Job specification: what to decompose, on what (logical) cluster, with
//! which backend and algorithm.

use crate::data::{FaceConfig, VideoConfig};
use crate::dist::chunkstore::SpillMode;
use crate::dist::{CostModel, ProcGrid};
use crate::ht::HtConfig;
use crate::tensor::DenseTensor;
use crate::ttrain::{SyntheticSparse, SyntheticTt, TtConfig};
use std::path::PathBuf;
use std::sync::Arc;

/// Which tensor-network decomposition a job runs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Decomposition {
    /// Tensor train (Alg 2 of the paper) — the left-to-right sweep.
    #[default]
    Tt,
    /// Hierarchical Tucker — the level-by-level sweep down the balanced
    /// dimension tree (`crate::ht`).
    Ht,
}

impl Decomposition {
    pub fn name(self) -> &'static str {
        match self {
            Decomposition::Tt => "tt",
            Decomposition::Ht => "ht",
        }
    }
}

impl std::str::FromStr for Decomposition {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "tt" => Ok(Decomposition::Tt),
            "ht" => Ok(Decomposition::Ht),
            _ => Err(format!("unknown decomposition '{s}' (tt|ht)")),
        }
    }
}

/// Where the input tensor comes from.
#[derive(Clone)]
pub enum InputSpec {
    /// §IV-A synthetic TT tensor — blocks are generated per rank without
    /// ever materializing the full tensor (scales to out-of-core sizes).
    Synthetic(SyntheticTt),
    /// Synthetic **sparse** tensor with controllable density — blocks are
    /// generated per rank as sparse chunks; the dense tensor is never
    /// materialized on the distributed path.
    SyntheticSparse(SyntheticSparse),
    /// Synthetic Yale-B-like face tensor (materialized once, shared).
    Faces(FaceConfig),
    /// Synthetic high-speed video tensor.
    Video(VideoConfig),
    /// A caller-provided dense tensor.
    Dense(Arc<DenseTensor<f64>>),
}

impl InputSpec {
    pub fn dims(&self) -> Vec<usize> {
        match self {
            InputSpec::Synthetic(s) => s.dims.clone(),
            InputSpec::SyntheticSparse(s) => s.dims.clone(),
            InputSpec::Faces(c) => vec![c.height, c.width, c.illuminations, c.subjects],
            InputSpec::Video(c) => vec![c.height, c.width, c.channels, c.frames],
            InputSpec::Dense(t) => t.dims().to_vec(),
        }
    }

    /// Materialize the full tensor when feasible (None for the synthetic
    /// inputs, which are generated blockwise).
    pub fn materialize(&self) -> Option<Arc<DenseTensor<f64>>> {
        match self {
            InputSpec::Synthetic(_) | InputSpec::SyntheticSparse(_) => None,
            InputSpec::Faces(c) => Some(Arc::new(crate::data::generate_faces(c))),
            InputSpec::Video(c) => Some(Arc::new(crate::data::generate_video(c))),
            InputSpec::Dense(t) => Some(t.clone()),
        }
    }

    pub fn label(&self) -> String {
        match self {
            InputSpec::Synthetic(s) => format!("synthetic{:?}r{:?}", s.dims, s.ranks),
            InputSpec::SyntheticSparse(s) => format!("sparse{:?}d{}", s.dims, s.density),
            InputSpec::Faces(_) => "faces".into(),
            InputSpec::Video(_) => "video".into(),
            InputSpec::Dense(t) => format!("dense{:?}", t.dims()),
        }
    }
}

/// Which compute backend the ranks use.
#[derive(Clone, Debug, Default)]
pub enum BackendChoice {
    #[default]
    Native,
    /// PJRT over the artifact directory (native fallback per shape).
    Pjrt(PathBuf),
}

/// A full decomposition job.
#[derive(Clone)]
pub struct JobConfig {
    pub input: InputSpec,
    pub grid: ProcGrid,
    /// Which network to decompose into (TT by default).
    pub decomp: Decomposition,
    /// TT parameters (used when `decomp == Decomposition::Tt`).
    pub tt: TtConfig,
    /// HT parameters (used when `decomp == Decomposition::Ht`).
    pub ht: HtConfig,
    pub backend: BackendChoice,
    pub spill: SpillMode,
    /// Model cluster timings with this α-β model (None = measured only).
    pub cost_model: Option<CostModel>,
    /// Compute the reconstruction error afterwards (requires materializing
    /// the tensor — skip for very large inputs).
    pub check_error: bool,
}

impl JobConfig {
    pub fn new(input: InputSpec, grid: ProcGrid) -> Self {
        JobConfig {
            input,
            grid,
            decomp: Decomposition::default(),
            tt: TtConfig::default(),
            ht: HtConfig::default(),
            backend: BackendChoice::Native,
            spill: SpillMode::Memory,
            cost_model: Some(CostModel::default()),
            check_error: true,
        }
    }
}
