//! Decomposition-as-a-service: the [`JobServer`].
//!
//! A queue of [`JobConfig`]s scheduled onto one shared
//! [`RankPool`](crate::dist::RankPool), with
//!
//! * **priority / fair-share admission** — strict head-of-line: the next
//!   job admitted is always the best pending entry by (priority desc,
//!   tenant fair-share deficit asc, submission order asc), where a
//!   tenant's deficit is the α-β-modeled cost ([`CostModel`]) of work
//!   already admitted on its behalf. The head is never overtaken: if it
//!   needs more ranks than are free, the server waits rather than
//!   backfilling a smaller job, which makes the admission *order* a pure
//!   function of the submitted set — independent of job durations and
//!   pool capacity (the determinism the `admission_log` tests pin down);
//! * **a fingerprint result cache** — finished jobs commit their `.dntt`
//!   artifact to a [`ResultCache`] keyed by [`JobConfig::fingerprint`].
//!   Resubmitting an identical config is a *cache hit*: the persisted
//!   artifact is returned and **no ranks are launched**. A fingerprint
//!   currently in flight is *coalesced*: the duplicate waits for the
//!   running job and shares its result. An *interrupted* job (crashed
//!   server, evicted artifact) left its `dntt-ckpt-v1` state in the
//!   entry's `ckpt/` directory, so the resubmitted config resumes from
//!   the last durable stage instead of starting over;
//! * **per-job isolation** — each admitted job runs on its own runner
//!   thread with its own [`SharedStore`](crate::dist::SharedStore),
//!   its own trace collector, and (optionally) its own fault plan, all
//!   armed thread-locally on the runner, so concurrent jobs cannot
//!   observe each other. Each job's output is **bitwise-identical** to
//!   running it alone through [`run_job`](crate::coordinator::run_job)
//!   (`tests/job_server.rs` proves this end to end).
//!
//! The full contract lives in `DESIGN.md` §2.11; operator workflows (the
//! `submit`/`serve`/`jobs` CLI, the spool, runbooks) in `OPERATIONS.md`.

use super::job::{JobConfig, ResumeMode};
use super::metrics::JobReport;
use super::run_job_leased;
use crate::dist::checkpoint::CheckpointPolicy;
use crate::dist::{faults, CostModel, FaultPlan, RankPool};
use crate::error::{DnttError, Result};
use crate::serve::ResultCache;
use crate::util::json::Json;
use std::collections::{HashMap, HashSet};
use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex};

/// Job priority classes, highest admitted first.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    Low,
    #[default]
    Normal,
    High,
}

impl Priority {
    pub fn name(self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }
}

impl std::str::FromStr for Priority {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, String> {
        match s {
            "low" => Ok(Priority::Low),
            "normal" => Ok(Priority::Normal),
            "high" => Ok(Priority::High),
            _ => Err(format!("unknown priority '{s}' (low|normal|high)")),
        }
    }
}

/// One submission: the job plus its scheduling envelope.
pub struct JobRequest {
    pub job: JobConfig,
    pub priority: Priority,
    /// Fair-share accounting bucket (e.g. a user or team name).
    pub tenant: String,
    /// Display label for listings and the admission log (defaults to the
    /// input's label).
    pub label: String,
    /// Deterministic fault plan armed on this job's runner thread only
    /// (testing/chaos drills; a no-op without the `fault-inject`
    /// feature).
    pub fault_plan: Option<Arc<FaultPlan>>,
}

impl JobRequest {
    pub fn new(job: JobConfig) -> Self {
        let label = job.input.label();
        JobRequest { job, priority: Priority::default(), tenant: "default".into(), label, fault_plan: None }
    }

    pub fn priority(mut self, p: Priority) -> Self {
        self.priority = p;
        self
    }

    pub fn tenant(mut self, t: impl Into<String>) -> Self {
        self.tenant = t.into();
        self
    }

    pub fn label(mut self, l: impl Into<String>) -> Self {
        self.label = l.into();
        self
    }

    pub fn fault_plan(mut self, plan: Arc<FaultPlan>) -> Self {
        self.fault_plan = Some(plan);
        self
    }
}

/// Server-assigned handle for one submission.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u64);

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job{}", self.0)
    }
}

/// How a submission finished.
pub struct JobOutcome {
    pub id: JobId,
    pub label: String,
    pub fingerprint: u64,
    /// Served from the committed cache without launching ranks.
    pub cache_hit: bool,
    /// Shared the result of an identical in-flight job (no ranks
    /// launched for *this* submission either).
    pub coalesced: bool,
    /// The committed `.dntt` artifact (None when the job errored).
    pub artifact: Option<PathBuf>,
    pub error: Option<String>,
    /// The full report, for submissions that actually executed.
    pub report: Option<Arc<JobReport>>,
}

impl JobOutcome {
    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }

    /// One row of `dntt jobs` / the server's `--json` output.
    pub fn to_json(&self) -> Json {
        let mut f = vec![
            ("id", Json::Num(self.id.0 as f64)),
            ("label", Json::Str(self.label.clone())),
            ("fingerprint", Json::Str(format!("{:016x}", self.fingerprint))),
            ("cache_hit", Json::Bool(self.cache_hit)),
            ("coalesced", Json::Bool(self.coalesced)),
        ];
        if let Some(a) = &self.artifact {
            f.push(("artifact", Json::Str(a.display().to_string())));
        }
        if let Some(e) = &self.error {
            f.push(("error", Json::Str(e.clone())));
        }
        if let Some(r) = &self.report {
            f.push(("wall_secs", Json::Num(r.wall_secs)));
            if let Some(e) = r.rel_error {
                f.push(("rel_error", Json::Num(e)));
            }
        }
        Json::obj(f)
    }
}

/// Counter snapshot ([`JobServer::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServerStats {
    pub submitted: u64,
    /// Jobs that actually ran on leased ranks.
    pub executed: u64,
    pub cache_hits: u64,
    pub coalesced: u64,
    /// Leases granted == worlds admitted onto the pool (a cache hit or
    /// coalesced duplicate grants none — the "no ranks launched" proof
    /// hook used by `tests/job_server.rs`).
    pub leases_granted: u64,
}

/// Server construction parameters.
pub struct ServerConfig {
    /// Worker ranks in the shared pool (an upper bound on any single
    /// job's grid size).
    pub pool_ranks: usize,
    /// Result-cache root ([`ResultCache`] layout).
    pub cache_dir: PathBuf,
    /// Force checkpointing into the cache's `ckpt/` directory for jobs
    /// that don't configure their own (default true). This is what makes
    /// interrupted jobs resumable on resubmit; it is fingerprint-neutral
    /// and bitwise-neutral by the `dntt-ckpt-v1` contract (DESIGN.md
    /// §2.7), so it cannot perturb results.
    pub checkpoint: bool,
    /// α-β model used to estimate job cost for fair-share accounting.
    pub cost_model: CostModel,
}

impl ServerConfig {
    pub fn new(pool_ranks: usize, cache_dir: impl Into<PathBuf>) -> Self {
        ServerConfig {
            pool_ranks,
            cache_dir: cache_dir.into(),
            checkpoint: true,
            cost_model: CostModel::default(),
        }
    }
}

/// Coarse a-priori cost of a job under the α-β model, in modeled seconds:
/// `d` global-reshape passes over the input plus a linear compute term.
/// Only *relative* magnitudes matter (fair-share deficits), so this
/// deliberately stays simple and deterministic.
pub fn estimate_cost(job: &JobConfig, m: &CostModel) -> f64 {
    let elems = job.input.storage_elems();
    let bytes = elems * 8.0;
    let d = job.input.dims().len() as f64;
    let p = job.grid.size().max(1) as f64;
    let hops = (p.max(2.0)).log2().ceil();
    let comm = d * (m.alpha * hops + bytes / (m.bandwidth * p));
    let compute = d * elems * 1e-9 * m.compute_scale / p;
    comm + compute
}

struct QueueEntry {
    id: JobId,
    seq: u64,
    fp: u64,
    est_cost: f64,
    req: JobRequest,
}

#[derive(Default)]
struct SrvState {
    queue: Vec<QueueEntry>,
    /// Fingerprints currently executing on leased ranks.
    running: HashSet<u64>,
    /// Duplicates parked on an in-flight fingerprint.
    waiters: HashMap<u64, Vec<QueueEntry>>,
    done: HashMap<JobId, Arc<JobOutcome>>,
    /// Admitted α-β cost per tenant (the fair-share deficit counter).
    tenant_cost: HashMap<String, f64>,
    log: Vec<String>,
    stats: ServerStats,
    next_seq: u64,
}

struct Inner {
    pool: RankPool,
    cache: ResultCache,
    checkpoint: bool,
    cost_model: CostModel,
    state: Mutex<SrvState>,
    cv: Condvar,
}

/// The multi-job coordinator. See the module docs for semantics.
///
/// Lifecycle: [`submit`](JobServer::submit) any number of jobs, then
/// [`drain`](JobServer::drain) to run them all to completion; outcomes
/// are then available via [`outcome`](JobServer::outcome). `submit` may
/// also be called from other threads while a `drain` is in progress.
pub struct JobServer {
    inner: Arc<Inner>,
}

impl JobServer {
    pub fn new(cfg: ServerConfig) -> Result<JobServer> {
        if cfg.pool_ranks == 0 {
            return Err(DnttError::config("job server needs at least one pool rank"));
        }
        let cache = ResultCache::open(&cfg.cache_dir)?;
        Ok(JobServer {
            inner: Arc::new(Inner {
                pool: RankPool::new(cfg.pool_ranks),
                cache,
                checkpoint: cfg.checkpoint,
                cost_model: cfg.cost_model,
                state: Mutex::new(SrvState::default()),
                cv: Condvar::new(),
            }),
        })
    }

    /// Ranks in the shared pool.
    pub fn pool_ranks(&self) -> usize {
        self.inner.pool.size()
    }

    /// The server's result cache (read access for serving/listing).
    pub fn cache(&self) -> &ResultCache {
        &self.inner.cache
    }

    /// Enqueue a job. Fails fast if the job's grid needs more ranks than
    /// the pool holds (it could never be admitted). The fingerprint is
    /// computed here, once, and reused for every cache decision.
    pub fn submit(&self, req: JobRequest) -> Result<JobId> {
        let p = req.job.grid.size();
        if p > self.inner.pool.size() {
            return Err(DnttError::config(format!(
                "job '{}' needs {p} ranks but the pool has {}",
                req.label,
                self.inner.pool.size()
            )));
        }
        if req.job.input.dims().len() != req.job.grid.dims().len() {
            return Err(DnttError::config(format!(
                "job '{}': grid has {} modes, tensor has {}",
                req.label,
                req.job.grid.dims().len(),
                req.job.input.dims().len()
            )));
        }
        let fp = req.job.fingerprint();
        let est_cost = estimate_cost(&req.job, &self.inner.cost_model);
        let mut st = self.inner.state.lock().unwrap();
        let seq = st.next_seq;
        st.next_seq += 1;
        let id = JobId(seq);
        st.stats.submitted += 1;
        st.queue.push(QueueEntry { id, seq, fp, est_cost, req });
        drop(st);
        self.inner.cv.notify_all();
        Ok(id)
    }

    /// Run every queued job to completion and return when the server is
    /// idle (queue empty, no world in flight). Call from one thread; the
    /// admitted jobs themselves run on per-job runner threads.
    pub fn drain(&self) {
        let inner = &self.inner;
        let mut st = inner.state.lock().unwrap();
        loop {
            // Admit from the head as long as the head can be resolved.
            loop {
                let Some(idx) = best_index(&st) else { break };
                let fp = st.queue[idx].fp;
                if st.running.contains(&fp) {
                    // Identical config in flight: park this duplicate on it.
                    let e = st.queue.remove(idx);
                    st.log.push(format!("dedup {} fp={fp:016x}", e.id));
                    st.stats.coalesced += 1;
                    st.waiters.entry(fp).or_default().push(e);
                    continue;
                }
                if let Some(hit) = inner.cache.lookup(fp) {
                    // Committed result on disk: serve it, launch nothing.
                    let e = st.queue.remove(idx);
                    st.log.push(format!("dedup {} fp={fp:016x}", e.id));
                    st.stats.cache_hits += 1;
                    let outcome = Arc::new(JobOutcome {
                        id: e.id,
                        label: e.req.label,
                        fingerprint: fp,
                        cache_hit: true,
                        coalesced: false,
                        artifact: Some(hit.artifact),
                        error: None,
                        report: None,
                    });
                    st.done.insert(e.id, outcome);
                    continue;
                }
                let p = st.queue[idx].req.job.grid.size();
                let Some(lease) = inner.pool.try_lease(p) else {
                    // Head-of-line blocking: wait for ranks to free up
                    // rather than admitting a smaller job out of order.
                    break;
                };
                let e = st.queue.remove(idx);
                st.stats.leases_granted += 1;
                *st.tenant_cost.entry(e.req.tenant.clone()).or_insert(0.0) += e.est_cost;
                st.log.push(format!(
                    "admit {} fp={fp:016x} tenant={} prio={} ranks={p} label={}",
                    e.id,
                    e.req.tenant,
                    e.req.priority.name(),
                    e.req.label
                ));
                st.running.insert(fp);
                let inner2 = Arc::clone(inner);
                std::thread::Builder::new()
                    .name(format!("dntt-runner-{}", e.id))
                    .spawn(move || run_one(inner2, e, lease))
                    .expect("spawning job runner");
            }
            if st.queue.is_empty() && st.running.is_empty() {
                break;
            }
            st = inner.cv.wait(st).unwrap();
        }
    }

    /// The outcome of a submission, once [`drain`](JobServer::drain) has
    /// processed it.
    pub fn outcome(&self, id: JobId) -> Option<Arc<JobOutcome>> {
        self.inner.state.lock().unwrap().done.get(&id).cloned()
    }

    /// All outcomes, sorted by job id.
    pub fn outcomes(&self) -> Vec<Arc<JobOutcome>> {
        let st = self.inner.state.lock().unwrap();
        let mut v: Vec<_> = st.done.values().cloned().collect();
        v.sort_by_key(|o| o.id);
        v
    }

    pub fn stats(&self) -> ServerStats {
        self.inner.state.lock().unwrap().stats
    }

    /// The deterministic admission log: one `admit`/`dedup` line per
    /// resolved submission, in resolution order. For a fixed submitted
    /// set this sequence does not depend on pool capacity or job timing
    /// (see the module docs); `dedup` covers both cache hits and
    /// coalesced duplicates, whose distinction *is* timing-dependent.
    pub fn admission_log(&self) -> Vec<String> {
        self.inner.state.lock().unwrap().log.clone()
    }
}

/// Index of the entry to resolve next: highest priority, then lowest
/// accumulated tenant cost, then lowest submission seq.
fn best_index(st: &SrvState) -> Option<usize> {
    let mut best: Option<usize> = None;
    for (i, e) in st.queue.iter().enumerate() {
        let Some(b) = best else {
            best = Some(i);
            continue;
        };
        if admits_before(e, &st.queue[b], &st.tenant_cost) {
            best = Some(i);
        }
    }
    best
}

fn admits_before(a: &QueueEntry, b: &QueueEntry, tenant_cost: &HashMap<String, f64>) -> bool {
    if a.req.priority != b.req.priority {
        return a.req.priority > b.req.priority;
    }
    let ca = tenant_cost.get(&a.req.tenant).copied().unwrap_or(0.0);
    let cb = tenant_cost.get(&b.req.tenant).copied().unwrap_or(0.0);
    match ca.total_cmp(&cb) {
        std::cmp::Ordering::Less => true,
        std::cmp::Ordering::Greater => false,
        std::cmp::Ordering::Equal => a.seq < b.seq,
    }
}

/// Execute one admitted job on its runner thread: arm per-job state,
/// run the world on the lease, commit the artifact, resolve waiters.
fn run_one(inner: Arc<Inner>, e: QueueEntry, lease: crate::dist::Lease) {
    let fp = e.fp;
    // Server-managed checkpointing: point the job at the cache entry's
    // ckpt/ directory so an interrupted run resumes on resubmit. The
    // fingerprint ignores these knobs, and checkpointing is
    // bitwise-neutral, so the effective job equals the submitted one.
    let mut job = e.req.job;
    if inner.checkpoint && job.checkpoint.is_none() {
        job.checkpoint = Some(CheckpointPolicy::new(inner.cache.ckpt_dir(fp)));
        job.resume = ResumeMode::Auto;
    }
    // Per-job fault plan, thread-local to this runner (the job's world
    // snapshots it at launch; concurrent jobs are unaffected).
    if let Some(plan) = &e.req.fault_plan {
        faults::arm(plan);
    }
    let result = run_job_leased(&lease, &job);
    faults::disarm();
    // Return the ranks before taking the state lock: admission sees the
    // freed capacity no later than the completion notification.
    drop(lease);

    let outcome = match result {
        Ok(mut report) => {
            report.fingerprint.get_or_insert(fp);
            let artifact = report.output.artifact();
            let meta = Json::obj(vec![
                ("label", Json::Str(e.req.label.clone())),
                ("tenant", Json::Str(e.req.tenant.clone())),
                ("decomp", Json::Str(report.decomp.name().into())),
                ("dims", Json::arr_usize(&report.dims)),
                ("grid", Json::arr_usize(&report.grid)),
                ("ranks", Json::arr_usize(&report.ranks)),
                ("wall_secs", Json::Num(report.wall_secs)),
            ]);
            match inner.cache.put(fp, &artifact, meta) {
                Ok(entry) => JobOutcome {
                    id: e.id,
                    label: e.req.label,
                    fingerprint: fp,
                    cache_hit: false,
                    coalesced: false,
                    artifact: Some(entry.artifact),
                    error: None,
                    report: Some(Arc::new(report)),
                },
                Err(err) => JobOutcome {
                    id: e.id,
                    label: e.req.label,
                    fingerprint: fp,
                    cache_hit: false,
                    coalesced: false,
                    artifact: None,
                    error: Some(format!("cache commit failed: {err}")),
                    report: Some(Arc::new(report)),
                },
            }
        }
        Err(err) => JobOutcome {
            id: e.id,
            label: e.req.label,
            fingerprint: fp,
            cache_hit: false,
            coalesced: false,
            artifact: None,
            error: Some(err.to_string()),
            report: None,
        },
    };

    let mut st = inner.state.lock().unwrap();
    st.running.remove(&fp);
    st.stats.executed += 1;
    // Coalesced duplicates share this job's result (including errors:
    // an identical config would fail identically, so re-running it for
    // the waiter would only repeat the failure).
    for w in st.waiters.remove(&fp).unwrap_or_default() {
        let shared = Arc::new(JobOutcome {
            id: w.id,
            label: w.req.label,
            fingerprint: fp,
            cache_hit: false,
            coalesced: true,
            artifact: outcome.artifact.clone(),
            error: outcome.error.clone(),
            report: None,
        });
        st.done.insert(w.id, shared);
    }
    st.done.insert(e.id, Arc::new(outcome));
    drop(st);
    inner.cv.notify_all();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::InputSpec;
    use crate::dist::ProcGrid;
    use crate::nmf::NmfConfig;
    use crate::ttrain::{SyntheticTt, TtConfig};

    fn quick_job(seed: u64, grid: Vec<usize>) -> JobConfig {
        JobConfig {
            tt: TtConfig {
                eps: 1e-6,
                nmf: NmfConfig { max_iters: 40, ..Default::default() },
                ..Default::default()
            },
            check_error: false,
            ..JobConfig::new(
                InputSpec::Synthetic(SyntheticTt::new(vec![6, 6, 6], vec![2, 2], seed)),
                ProcGrid::new(grid).unwrap(),
            )
        }
    }

    fn temp_server(tag: &str, pool: usize) -> JobServer {
        let dir = std::env::temp_dir()
            .join(format!("dntt-srv-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        JobServer::new(ServerConfig::new(pool, dir)).unwrap()
    }

    #[test]
    fn submit_drain_outcome_and_cache_hit() {
        let srv = temp_server("basic", 4);
        let id1 = srv.submit(JobRequest::new(quick_job(3, vec![2, 1, 2]))).unwrap();
        srv.drain();
        let o1 = srv.outcome(id1).expect("resolved");
        assert!(o1.is_ok(), "job failed: {:?}", o1.error);
        assert!(!o1.cache_hit);
        assert!(o1.artifact.as_ref().unwrap().is_file());
        let leases_before = srv.stats().leases_granted;
        // Identical config again: a hit, no new lease.
        let id2 = srv.submit(JobRequest::new(quick_job(3, vec![2, 1, 2]))).unwrap();
        srv.drain();
        let o2 = srv.outcome(id2).unwrap();
        assert!(o2.cache_hit);
        assert_eq!(o2.artifact, o1.artifact);
        assert_eq!(srv.stats().leases_granted, leases_before);
        let _ = std::fs::remove_dir_all(srv.cache().dir());
    }

    #[test]
    fn oversized_job_rejected_at_submit() {
        let srv = temp_server("oversize", 2);
        let err = srv.submit(JobRequest::new(quick_job(1, vec![2, 1, 2]))).unwrap_err();
        assert!(err.to_string().contains("pool"), "{err}");
        let _ = std::fs::remove_dir_all(srv.cache().dir());
    }

    #[test]
    fn admission_order_is_priority_then_fair_share_then_seq() {
        // Pool sized so jobs serialize; order still must come purely from
        // the scheduling key.
        let srv = temp_server("order", 4);
        let mk = |seed: u64| quick_job(seed, vec![2, 1, 2]);
        let a0 = srv
            .submit(JobRequest::new(mk(10)).tenant("a").priority(Priority::Normal))
            .unwrap();
        let a1 = srv
            .submit(JobRequest::new(mk(11)).tenant("a").priority(Priority::Normal))
            .unwrap();
        let b0 = srv
            .submit(JobRequest::new(mk(12)).tenant("b").priority(Priority::Normal))
            .unwrap();
        let hi = srv
            .submit(JobRequest::new(mk(13)).tenant("c").priority(Priority::High))
            .unwrap();
        srv.drain();
        let log = srv.admission_log();
        let order: Vec<String> =
            log.iter().map(|l| l.split_whitespace().nth(1).unwrap().to_string()).collect();
        // High first (despite last submission); then within Normal the
        // tenants alternate — after a0, tenant a has accumulated cost,
        // so b0 overtakes the earlier-submitted a1 (fair share).
        assert_eq!(
            order,
            vec![hi.to_string(), a0.to_string(), b0.to_string(), a1.to_string()],
            "log: {log:?}"
        );
        let _ = std::fs::remove_dir_all(srv.cache().dir());
    }

    #[test]
    fn duplicate_in_one_batch_executes_once() {
        let srv = temp_server("dedup", 4);
        let id1 = srv.submit(JobRequest::new(quick_job(7, vec![2, 1, 2]))).unwrap();
        let id2 = srv.submit(JobRequest::new(quick_job(7, vec![2, 1, 2]))).unwrap();
        srv.drain();
        let s = srv.stats();
        assert_eq!(s.executed, 1, "identical configs must not both run");
        assert_eq!(s.cache_hits + s.coalesced, 1);
        let o1 = srv.outcome(id1).unwrap();
        let o2 = srv.outcome(id2).unwrap();
        assert!(o1.is_ok() && o2.is_ok());
        assert_eq!(o1.artifact, o2.artifact);
        let _ = std::fs::remove_dir_all(srv.cache().dir());
    }
}
