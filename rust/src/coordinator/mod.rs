//! The L3 coordinator: turns a [`JobConfig`] into thread ranks, feeds them
//! their tensor blocks, runs the distributed nTT or nHT (per
//! [`Decomposition`]), and aggregates results, timings and cluster-model
//! estimates into a [`JobReport`].
//!
//! Above the single-job entry points sits the service layer:
//!
//! * [`server`] — the [`JobServer`]: many queued jobs scheduled onto one
//!   shared rank pool with priority/fair-share admission, per-job
//!   isolation, and a fingerprint result cache (DESIGN.md §2.11);
//! * [`spool`] — the on-disk `dntt-job-v1` queue connecting
//!   `dntt submit` to `dntt serve`.

pub mod job;
pub mod metrics;
pub mod server;
pub mod spool;

pub use job::{BackendChoice, Decomposition, InputSpec, JobConfig, ResumeMode};
pub use metrics::{DecompOutput, JobReport, ModelResidual};
pub use server::{
    JobId, JobOutcome, JobRequest, JobServer, Priority, ServerConfig, ServerStats,
};
pub use spool::{JobSpec, PendingJob, Spool};

use crate::dist::checkpoint::{self, CkptCtx};
use crate::dist::{faults, Comm, Lease, SharedStore, SpillMode, TensorBlock};
use crate::error::{DnttError, Result};
use crate::runtime::{NativeBackend, PjrtBackend, PjrtEngine};
use crate::ttrain::driver::{dist_ntt, extract_block};
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::Instant;

/// Upper bound on world relaunches after lost ranks within one
/// `run_job` call (each injected kill fires at most once, so real fault
/// plans converge long before this; the cap only stops a pathological
/// environment from relaunching forever).
const MAX_RESTARTS: usize = 32;

/// Run a decomposition job end-to-end.
///
/// ```
/// use dntt::coordinator::{run_job, InputSpec, JobConfig};
/// use dntt::dist::ProcGrid;
/// use dntt::ttrain::SyntheticTt;
///
/// let job = JobConfig::new(
///     InputSpec::Synthetic(SyntheticTt::new(vec![4, 4, 4], vec![2, 2], 7)),
///     ProcGrid::new(vec![1, 1, 1]).unwrap(),
/// );
/// let report = run_job(&job).unwrap();
/// assert_eq!(report.ranks.len(), 4); // [1, r1, r2, 1] for a 3-mode TT
/// assert!(report.output.is_nonneg());
/// assert!(report.rel_error.unwrap() < 1.0);
/// ```
pub fn run_job(job: &JobConfig) -> Result<JobReport> {
    run_job_impl(job, Exec::Spawn)
}

/// Run a decomposition job on ranks leased from a
/// [`RankPool`](crate::dist::RankPool) instead of freshly spawned
/// threads — the [`JobServer`] execution path. The lease must hold
/// exactly the job's grid size. The output is bitwise-identical to
/// [`run_job`] on the same config: both paths launch the same rank body,
/// and world ranks are lease positions, independent of which pool
/// workers host them.
pub fn run_job_leased(lease: &Lease, job: &JobConfig) -> Result<JobReport> {
    if lease.size() != job.grid.size() {
        return Err(DnttError::config(format!(
            "lease holds {} ranks, job grid needs {}",
            lease.size(),
            job.grid.size()
        )));
    }
    run_job_impl(job, Exec::Lease(lease))
}

/// How [`run_job_impl`] launches the SPMD world for each attempt.
#[derive(Clone, Copy)]
enum Exec<'a> {
    /// `p` fresh scoped threads per attempt ([`Comm::run`]).
    Spawn,
    /// Ranks leased from a shared pool ([`Lease::run_world`]); relaunch
    /// attempts after a lost rank reuse the same lease.
    Lease(&'a Lease),
}

fn run_job_impl(job: &JobConfig, exec: Exec<'_>) -> Result<JobReport> {
    let dims = job.input.dims();
    if dims.len() != job.grid.dims().len() {
        return Err(DnttError::config(format!(
            "grid has {} modes, tensor has {}",
            job.grid.dims().len(),
            dims.len()
        )));
    }
    let p = job.grid.size();
    let grid2 = job.grid.to_2d();
    // File inputs: open + validate the chunk set once; ranks adopt their
    // chunk files through the shared handle.
    let chunkset = match &job.input {
        InputSpec::File { dir, .. } => {
            let cs = crate::tensor::ChunkSet::open(dir)?;
            if cs.grid() != job.grid.dims() {
                return Err(DnttError::config(format!(
                    "chunk set grid {:?} must equal the processor grid {:?} \
                     (dntt-chunks-v1 maps chunk c to rank c)",
                    cs.grid(),
                    job.grid.dims()
                )));
            }
            Some(Arc::new(cs))
        }
        _ => None,
    };
    // Resolve the effective spill mode: a memory budget over a pure
    // in-memory store upgrades to mmap-backed spill in a temp directory,
    // since only mapped chunks can stay off the heap (DESIGN.md §2.12).
    let spill = match (&job.spill, job.budget) {
        (SpillMode::Memory, Some(b)) => {
            static OO_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
            let dir = std::env::temp_dir().join(format!(
                "dntt_oo_{}_{}",
                std::process::id(),
                OO_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
            ));
            log::info!(
                "memory budget {b} B with in-memory store: upgrading to mmap-backed spill at {dir:?}"
            );
            SpillMode::Mmap(dir)
        }
        _ => job.spill.clone(),
    };
    let dense = job.input.materialize();
    let engine: Option<Arc<PjrtEngine>> = match &job.backend {
        BackendChoice::Native => None,
        BackendChoice::Pjrt(dir) => Some(PjrtEngine::start(dir)?),
    };

    // The fingerprint is only consumed through CkptCtx; for Dense inputs
    // it hashes the whole tensor, so skip it when no checkpointing is
    // configured (the common path).
    let config_hash = if job.checkpoint.is_some() { job.fingerprint() } else { 0 };
    // One trace collector for the whole job: relaunch attempts append
    // further per-rank rings for the same rank ids, which the report
    // aggregates (the events of a lost attempt are kept, not discarded).
    let collector = job.trace.map(crate::obs::TraceCollector::new);
    let t0 = Instant::now();
    // Under `ResumeMode::Auto` the first launch already tries the
    // checkpoint directory (a missing manifest is a fresh start); after a
    // lost rank the world is relaunched with `resume` forced on.
    let mut resume = job.resume == ResumeMode::Auto;
    let mut attempt = 0usize;
    // Peak resident bytes across attempts (max, not last: a lost attempt
    // still occupied memory).
    let mut peak_resident = 0u64;
    let mut outs: Vec<Result<DecompOutput>> = loop {
        // A fresh store per attempt: a poisoned world may leave
        // partially-published arrays behind (the store's Drop cleans any
        // spill files).
        let store = SharedStore::new(spill.clone());
        store.set_keep_spill(job.keep_spill);
        store.set_budget(job.budget);
        let mem = Arc::clone(store.stats());
        let ckpt_ctx = job
            .checkpoint
            .clone()
            .map(|policy| CkptCtx { policy, config_hash, resume });
        let input = job.input.clone();
        let chunkset2 = chunkset.clone();
        let grid = job.grid.clone();
        let decomp = job.decomp;
        let tt_cfg = job.tt.clone();
        let ht_cfg = job.ht.clone();
        let kcfg = job.kernel_cfg();
        let dims2 = dims.clone();
        let dense2 = dense.clone();
        let eng2 = engine.clone();
        let fired_before = faults::armed().map(|pl| pl.fired_count()).unwrap_or(0);
        // Arm only across the world launch — `Comm::run` snapshots the
        // collector when it spawns ranks, and disarming immediately after
        // keeps the coordinator slot clean on every exit path.
        if let Some(c) = &collector {
            crate::obs::arm(c);
        }
        // The rank body: all captures are owned (`'static`) and `Clone`,
        // so the same closure serves both launchers.
        let body = move |mut world: Comm| {
            let rank = world.rank();
            // Build this rank's block (sparse inputs stay sparse end to end).
            let block = match (&input, &dense2) {
                (InputSpec::Synthetic(s), _) => TensorBlock::Dense(s.block(&grid, rank)?),
                (InputSpec::SyntheticSparse(s), _) => TensorBlock::Sparse(s.block(&grid, rank)),
                // Chunk c feeds rank c: the file is adopted in place, so
                // the block never touches this rank's heap.
                (InputSpec::File { .. }, _) => {
                    chunkset2.as_ref().expect("chunk set opened for File inputs").block(rank)?
                }
                (_, Some(t)) => TensorBlock::Dense(extract_block(t, &grid, rank)),
                _ => unreachable!("non-synthetic, non-file inputs materialize"),
            };
            let (mut row, mut col) = grid2.make_subcomms(&mut world);
            // One driver call per (decomposition, backend) choice.
            let run = |world: &mut Comm,
                       row: &mut Comm,
                       col: &mut Comm,
                       backend: &dyn crate::runtime::ComputeBackend|
             -> Result<DecompOutput> {
                match decomp {
                    Decomposition::Tt => dist_ntt(
                        world, row, col, &store, &grid, grid2, &dims2, block, backend, &tt_cfg,
                        kcfg, ckpt_ctx.as_ref(),
                    )
                    .map(DecompOutput::Tt),
                    Decomposition::Ht => crate::ht::dist_nht(
                        world, row, col, &store, &grid, grid2, &dims2, block, backend, &ht_cfg,
                        kcfg, ckpt_ctx.as_ref(),
                    )
                    .map(DecompOutput::Ht),
                }
            };
            match &eng2 {
                Some(e) => {
                    let backend = PjrtBackend::new(Arc::clone(e));
                    run(&mut world, &mut row, &mut col, &backend)
                }
                None => run(&mut world, &mut row, &mut col, &NativeBackend),
            }
        };
        let world_run = catch_unwind(AssertUnwindSafe(|| match exec {
            Exec::Spawn => Comm::run(p, body),
            Exec::Lease(lease) => lease.run_world(body),
        }));
        crate::obs::disarm();
        peak_resident = peak_resident.max(mem.peak_resident_bytes());
        match world_run {
            Ok(outs) => break outs,
            Err(payload) => {
                // Distinguish an injected rank death (the armed fault
                // plan fired during this attempt) from a genuine bug.
                let plan = faults::armed();
                let fired_now = plan.as_ref().map(|pl| pl.fired_count()).unwrap_or(0);
                if fired_now > fired_before {
                    let kill = plan.unwrap().last_fired().expect("a kill fired");
                    let lost = DnttError::RankLost { rank: kill.rank, op: kill.op };
                    if job.resume == ResumeMode::Auto
                        && job.checkpoint.is_some()
                        && attempt < MAX_RESTARTS
                    {
                        let dir = &job.checkpoint.as_ref().unwrap().dir;
                        log::warn!(
                            "{lost}; last durable checkpoint: {} completed stage(s) in {dir:?}; \
                             relaunching the world (attempt {})",
                            checkpoint::stages_done(dir).unwrap_or(0),
                            attempt + 1
                        );
                        attempt += 1;
                        resume = true;
                        continue;
                    }
                    return Err(lost);
                }
                resume_unwind(payload);
            }
        }
    };
    let wall_secs = t0.elapsed().as_secs_f64();
    // An auto-upgraded spill dir is ours to tidy: the store's Drop already
    // removed the chunk files, so this only deletes the empty directory
    // (and silently leaves it when keep_spill retained the files).
    if let (SpillMode::Memory, SpillMode::Mmap(d)) = (&job.spill, &spill) {
        let _ = std::fs::remove_dir(d);
    }
    // Propagate the first error, if any.
    let mut output = None;
    for o in outs.drain(..) {
        match o {
            Ok(v) if output.is_none() => output = Some(v),
            Ok(_) => {}
            Err(e) => return Err(e),
        }
    }
    let output = output.unwrap();

    // Reconstruction error against the input (small tensors only).
    let rel_error = if job.check_error {
        match (&job.input, &dense) {
            (InputSpec::Synthetic(s), _) if s.len() <= 20_000_000 => {
                Some(output.rel_error(&s.dense()))
            }
            (InputSpec::SyntheticSparse(s), _) if s.len() <= 20_000_000 => {
                Some(output.rel_error(&s.dense()))
            }
            (_, Some(t)) => Some(output.rel_error(t)),
            _ => None,
        }
    } else {
        None
    };

    let modeled = job.cost_model.map(|m| m.model_breakdown(output.breakdown(), p));
    let pjrt_hits = engine
        .as_ref()
        .map(|e| e.stats.hits.load(std::sync::atomic::Ordering::Relaxed))
        .unwrap_or(0);
    let obs = collector.map(|c| c.take_report());
    let mut report = JobReport::new(job, output, wall_secs, rel_error, modeled, pjrt_hits, obs);
    report.peak_resident_bytes = Some(peak_resident);
    report.budget_bytes = job.budget;
    if job.checkpoint.is_some() {
        // Already computed above for the checkpoint manifests; surface it
        // so server-run reports carry their cache key.
        report.fingerprint = Some(config_hash);
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::ProcGrid;
    use crate::nmf::NmfConfig;
    use crate::ttrain::{SyntheticTt, TtConfig};

    fn quick_tt() -> TtConfig {
        TtConfig {
            eps: 1e-6,
            nmf: NmfConfig { max_iters: 60, ..Default::default() },
            ..Default::default()
        }
    }

    #[test]
    fn synthetic_job_end_to_end() {
        let job = JobConfig {
            tt: quick_tt(),
            ..JobConfig::new(
                InputSpec::Synthetic(SyntheticTt::new(vec![6, 6, 6], vec![2, 2], 3)),
                ProcGrid::new(vec![2, 1, 2]).unwrap(),
            )
        };
        let rep = run_job(&job).unwrap();
        assert_eq!(rep.ranks, vec![1, 2, 2, 1]);
        assert!(rep.rel_error.unwrap() < 0.1);
        assert!(rep.compression > 1.0);
        assert!(rep.wall_secs > 0.0);
        assert!(rep.modeled.is_some());
    }

    #[test]
    fn faces_job_runs() {
        let job = JobConfig {
            tt: quick_tt(),
            ..JobConfig::new(
                InputSpec::Faces(crate::data::FaceConfig {
                    height: 12,
                    width: 10,
                    illuminations: 6,
                    subjects: 4,
                    seed: 1,
                }),
                ProcGrid::new(vec![2, 1, 1, 1]).unwrap(),
            )
        };
        let rep = run_job(&job).unwrap();
        assert!(rep.rel_error.unwrap() < 0.6);
        assert!(rep.output.is_nonneg());
    }

    #[test]
    fn ht_job_end_to_end_with_per_node_stages() {
        let job = JobConfig {
            decomp: Decomposition::Ht,
            ht: crate::ht::HtConfig {
                eps: 1e-6,
                nmf: crate::nmf::NmfConfig { max_iters: 80, ..Default::default() },
                ..Default::default()
            },
            ..JobConfig::new(
                InputSpec::Synthetic(SyntheticTt::new(vec![6, 6, 6], vec![2, 2], 3)),
                ProcGrid::new(vec![2, 1, 2]).unwrap(),
            )
        };
        let rep = run_job(&job).unwrap();
        let out = rep.output.ht().expect("HT job returns an HT output");
        // d = 3 → 2 interior nodes → 4 per-tree-node stage records, each
        // with a wall-time entry.
        assert_eq!(out.stages.len(), 4);
        assert!(out.stages.iter().all(|s| s.secs >= 0.0 && s.rank >= 1));
        assert_eq!(rep.ranks.len(), out.ht.tree().len());
        assert!(rep.rel_error.unwrap() < 0.2);
        assert!(rep.compression > 0.0);
        assert!(rep.output.is_nonneg());
        assert!(rep.modeled.is_some());
    }

    #[test]
    fn grid_mismatch_rejected() {
        let job = JobConfig::new(
            InputSpec::Synthetic(SyntheticTt::new(vec![4, 4], vec![2], 1)),
            ProcGrid::new(vec![2, 2, 2]).unwrap(),
        );
        assert!(run_job(&job).is_err());
    }
}
