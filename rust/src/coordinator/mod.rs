//! The L3 coordinator: turns a [`JobConfig`] into thread ranks, feeds them
//! their tensor blocks, runs the distributed nTT, and aggregates results,
//! timings and cluster-model estimates into a [`JobReport`].

pub mod job;
pub mod metrics;

pub use job::{BackendChoice, InputSpec, JobConfig};
pub use metrics::JobReport;

use crate::dist::{Comm, SharedStore};
use crate::error::{DnttError, Result};
use crate::runtime::{NativeBackend, PjrtBackend, PjrtEngine};
use crate::ttrain::driver::{dist_ntt, extract_block};
use crate::ttrain::TtOutput;
use std::sync::Arc;
use std::time::Instant;

/// Run a decomposition job end-to-end.
pub fn run_job(job: &JobConfig) -> Result<JobReport> {
    let dims = job.input.dims();
    if dims.len() != job.grid.dims().len() {
        return Err(DnttError::config(format!(
            "grid has {} modes, tensor has {}",
            job.grid.dims().len(),
            dims.len()
        )));
    }
    let p = job.grid.size();
    let grid2 = job.grid.to_2d();
    let store = SharedStore::new(job.spill.clone());
    let dense = job.input.materialize();
    let engine: Option<Arc<PjrtEngine>> = match &job.backend {
        BackendChoice::Native => None,
        BackendChoice::Pjrt(dir) => Some(PjrtEngine::start(dir)?),
    };

    let t0 = Instant::now();
    let input = job.input.clone();
    let grid = job.grid.clone();
    let tt_cfg = job.tt.clone();
    let dims2 = dims.clone();
    let dense2 = dense.clone();
    let eng2 = engine.clone();
    let mut outs: Vec<Result<TtOutput>> = Comm::run(p, move |mut world| {
        let rank = world.rank();
        // Build this rank's block.
        let block = match (&input, &dense2) {
            (InputSpec::Synthetic(s), _) => s.block(&grid, rank)?,
            (_, Some(t)) => extract_block(t, &grid, rank),
            _ => unreachable!("non-synthetic inputs materialize"),
        };
        let (mut row, mut col) = grid2.make_subcomms(&mut world);
        match &eng2 {
            Some(e) => {
                let backend = PjrtBackend::new(Arc::clone(e));
                dist_ntt(
                    &mut world, &mut row, &mut col, &store, &grid, grid2, &dims2, block,
                    &backend, &tt_cfg,
                )
            }
            None => dist_ntt(
                &mut world, &mut row, &mut col, &store, &grid, grid2, &dims2, block,
                &NativeBackend, &tt_cfg,
            ),
        }
    });
    let wall_secs = t0.elapsed().as_secs_f64();
    // Propagate the first error, if any.
    let mut output = None;
    for o in outs.drain(..) {
        match o {
            Ok(v) if output.is_none() => output = Some(v),
            Ok(_) => {}
            Err(e) => return Err(e),
        }
    }
    let output = output.unwrap();

    // Reconstruction error against the input (small tensors only).
    let rel_error = if job.check_error {
        match (&job.input, &dense) {
            (InputSpec::Synthetic(s), _) if s.len() <= 20_000_000 => {
                Some(output.tt.rel_error(&s.dense()))
            }
            (_, Some(t)) => Some(output.tt.rel_error(t)),
            _ => None,
        }
    } else {
        None
    };

    let modeled = job.cost_model.map(|m| m.model_breakdown(&output.breakdown, p));
    let pjrt_hits = engine
        .as_ref()
        .map(|e| e.stats.hits.load(std::sync::atomic::Ordering::Relaxed))
        .unwrap_or(0);
    Ok(JobReport::new(job, output, wall_secs, rel_error, modeled, pjrt_hits))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::ProcGrid;
    use crate::nmf::NmfConfig;
    use crate::ttrain::{SyntheticTt, TtConfig};

    fn quick_tt() -> TtConfig {
        TtConfig {
            eps: 1e-6,
            nmf: NmfConfig { max_iters: 60, ..Default::default() },
            ..Default::default()
        }
    }

    #[test]
    fn synthetic_job_end_to_end() {
        let job = JobConfig {
            tt: quick_tt(),
            ..JobConfig::new(
                InputSpec::Synthetic(SyntheticTt::new(vec![6, 6, 6], vec![2, 2], 3)),
                ProcGrid::new(vec![2, 1, 2]).unwrap(),
            )
        };
        let rep = run_job(&job).unwrap();
        assert_eq!(rep.ranks, vec![1, 2, 2, 1]);
        assert!(rep.rel_error.unwrap() < 0.1);
        assert!(rep.compression > 1.0);
        assert!(rep.wall_secs > 0.0);
        assert!(rep.modeled.is_some());
    }

    #[test]
    fn faces_job_runs() {
        let job = JobConfig {
            tt: quick_tt(),
            ..JobConfig::new(
                InputSpec::Faces(crate::data::FaceConfig {
                    height: 12,
                    width: 10,
                    illuminations: 6,
                    subjects: 4,
                    seed: 1,
                }),
                ProcGrid::new(vec![2, 1, 1, 1]).unwrap(),
            )
        };
        let rep = run_job(&job).unwrap();
        assert!(rep.rel_error.unwrap() < 0.6);
        assert!(rep.output.tt.is_nonneg());
    }

    #[test]
    fn grid_mismatch_rejected() {
        let job = JobConfig::new(
            InputSpec::Synthetic(SyntheticTt::new(vec![4, 4], vec![2], 1)),
            ProcGrid::new(vec![2, 2, 2]).unwrap(),
        );
        assert!(run_job(&job).is_err());
    }
}
