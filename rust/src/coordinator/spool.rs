//! The on-disk job spool: how `dntt submit` hands work to `dntt serve`.
//!
//! A spool is a directory of `dntt-job-v1` JSON job specs:
//!
//! ```text
//! <spool>/
//!   pending/job000000.json     # submitted, not yet processed
//!   done/job000000.json        # the spec, moved here once resolved
//!   done/job000000.outcome.json# the server's JobOutcome row
//! ```
//!
//! [`JobSpec`] is the serializable subset of [`JobConfig`] the CLI can
//! express (the `decompose` flags plus the scheduling envelope:
//! priority, tenant, label, trace). `dntt submit` appends a spec to
//! `pending/`; `dntt serve` turns each into a
//! [`JobRequest`](super::server::JobRequest), drains the
//! [`JobServer`](super::server::JobServer), and moves specs to `done/`
//! with their outcome rows. Files are claimed with `create_new`, so
//! concurrent submitters never collide; specs sort and execute by their
//! sequence number (submission order).

use super::job::{Decomposition, InputSpec, JobConfig};
use super::server::{JobRequest, Priority};
use crate::data::{FaceConfig, VideoConfig};
use crate::dist::ProcGrid;
use crate::error::{DnttError, Result};
use crate::ht::HtConfig;
use crate::nmf::{NmfAlgo, NmfConfig};
use crate::ttrain::{SyntheticSparse, SyntheticTt, TtConfig};
use crate::util::json::Json;
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

/// `JobSpec` serialization format tag.
pub const JOB_FORMAT: &str = "dntt-job-v1";

/// A serializable decomposition job: what `dntt submit` writes and
/// `dntt serve` runs. Mirrors the `dntt decompose` flag surface.
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    /// Input kind: `synthetic|sparse|faces|video|file`.
    pub input: String,
    /// `dntt-chunks-v1` directory (`file` input).
    pub file: Option<PathBuf>,
    /// Chunk-store memory budget in MiB (0 = unbounded).
    pub budget_mb: u64,
    /// Tensor dims (synthetic|sparse inputs).
    pub dims: Vec<usize>,
    /// Generator TT ranks (synthetic input; `dims.len() - 1` entries).
    pub true_ranks: Vec<usize>,
    /// Nonzero fraction in `(0, 1]` (sparse input).
    pub density: f64,
    pub seed: u64,
    pub decomp: Decomposition,
    /// Processor grid, one entry per tensor mode.
    pub grid: Vec<usize>,
    /// Per-stage rank-selection threshold.
    pub eps: f64,
    /// Fixed stage ranks (skip the SVD rank selection).
    pub fixed_ranks: Option<Vec<usize>>,
    /// NMF update rule: `bcd|mu|hals`.
    pub algo: String,
    /// NMF iterations per stage.
    pub iters: usize,
    pub prune: bool,
    pub check_error: bool,
    /// Record per-rank traces; fills the job's metrics envelope.
    pub trace: bool,
    /// GEMM/SpMM kernel policy: `auto|scalar|avx2|avx512|neon`
    /// (bitwise-neutral; `DNTT_KERNEL` on the serving host overrides).
    pub kernel: String,
    /// Intra-rank worker threads for the packed GEMM/SpMM panel loop.
    pub threads_per_rank: usize,
    pub priority: Priority,
    pub tenant: String,
    /// Display label (defaults to the input's label).
    pub label: Option<String>,
}

impl Default for JobSpec {
    fn default() -> Self {
        // Matches the `dntt decompose` defaults.
        JobSpec {
            input: "synthetic".into(),
            file: None,
            budget_mb: 0,
            dims: vec![16, 16, 16, 16],
            true_ranks: vec![4, 4, 4],
            density: 0.01,
            seed: 42,
            decomp: Decomposition::Tt,
            grid: vec![1, 1, 1, 1],
            eps: 0.01,
            fixed_ranks: None,
            algo: "bcd".into(),
            iters: 100,
            prune: false,
            check_error: true,
            trace: false,
            kernel: "auto".into(),
            threads_per_rank: 1,
            priority: Priority::Normal,
            tenant: "default".into(),
            label: None,
        }
    }
}

impl JobSpec {
    /// The CI/perf-smoke preset — identical tensor and grid to
    /// `dntt decompose --smoke` so solo and served smoke runs share
    /// fingerprints.
    pub fn smoke(seed: u64) -> JobSpec {
        JobSpec {
            dims: vec![8, 8, 8, 8],
            true_ranks: vec![3, 3, 3],
            grid: vec![2, 2, 1, 1],
            seed,
            ..JobSpec::default()
        }
    }

    pub fn to_json(&self) -> Json {
        let mut f = vec![
            ("format", Json::Str(JOB_FORMAT.into())),
            ("input", Json::Str(self.input.clone())),
            ("dims", Json::arr_usize(&self.dims)),
            ("true_ranks", Json::arr_usize(&self.true_ranks)),
            ("density", Json::Num(self.density)),
            ("seed", Json::Num(self.seed as f64)),
            ("decomp", Json::Str(self.decomp.name().into())),
            ("grid", Json::arr_usize(&self.grid)),
            ("eps", Json::Num(self.eps)),
            ("algo", Json::Str(self.algo.clone())),
            ("iters", Json::Num(self.iters as f64)),
            ("prune", Json::Bool(self.prune)),
            ("check_error", Json::Bool(self.check_error)),
            ("trace", Json::Bool(self.trace)),
            ("kernel", Json::Str(self.kernel.clone())),
            ("threads_per_rank", Json::Num(self.threads_per_rank as f64)),
            ("priority", Json::Str(self.priority.name().into())),
            ("tenant", Json::Str(self.tenant.clone())),
        ];
        if let Some(r) = &self.fixed_ranks {
            f.push(("fixed_ranks", Json::arr_usize(r)));
        }
        if let Some(l) = &self.label {
            f.push(("label", Json::Str(l.clone())));
        }
        if let Some(p) = &self.file {
            f.push(("file", Json::Str(p.to_string_lossy().into_owned())));
        }
        if self.budget_mb > 0 {
            f.push(("budget_mb", Json::Num(self.budget_mb as f64)));
        }
        Json::obj(f)
    }

    pub fn from_json(j: &Json) -> Result<JobSpec> {
        let bad = |what: &str| DnttError::config(format!("job spec: bad or missing '{what}'"));
        match j.get("format").as_str() {
            Some(JOB_FORMAT) => {}
            Some(other) => {
                return Err(DnttError::config(format!(
                    "job spec: format '{other}', expected '{JOB_FORMAT}'"
                )))
            }
            None => return Err(bad("format")),
        }
        let d = JobSpec::default();
        let usize_arr = |key: &str, dflt: &[usize]| -> Result<Vec<usize>> {
            match j.get(key) {
                Json::Null => Ok(dflt.to_vec()),
                v => v
                    .as_arr()
                    .ok_or_else(|| bad(key))?
                    .iter()
                    .map(|x| x.as_usize().ok_or_else(|| bad(key)))
                    .collect(),
            }
        };
        let str_or = |key: &str, dflt: &str| -> Result<String> {
            match j.get(key) {
                Json::Null => Ok(dflt.to_string()),
                v => v.as_str().map(str::to_string).ok_or_else(|| bad(key)),
            }
        };
        let num_or = |key: &str, dflt: f64| -> Result<f64> {
            match j.get(key) {
                Json::Null => Ok(dflt),
                v => v.as_f64().ok_or_else(|| bad(key)),
            }
        };
        let bool_or = |key: &str, dflt: bool| -> Result<bool> {
            match j.get(key) {
                Json::Null => Ok(dflt),
                v => v.as_bool().ok_or_else(|| bad(key)),
            }
        };
        let fixed_ranks = match j.get("fixed_ranks") {
            Json::Null => None,
            v => Some(
                v.as_arr()
                    .ok_or_else(|| bad("fixed_ranks"))?
                    .iter()
                    .map(|x| x.as_usize().ok_or_else(|| bad("fixed_ranks")))
                    .collect::<Result<Vec<usize>>>()?,
            ),
        };
        let label = match j.get("label") {
            Json::Null => None,
            v => Some(v.as_str().ok_or_else(|| bad("label"))?.to_string()),
        };
        let file = match j.get("file") {
            Json::Null => None,
            v => Some(PathBuf::from(v.as_str().ok_or_else(|| bad("file"))?)),
        };
        Ok(JobSpec {
            input: str_or("input", &d.input)?,
            file,
            budget_mb: num_or("budget_mb", 0.0)? as u64,
            dims: usize_arr("dims", &d.dims)?,
            true_ranks: usize_arr("true_ranks", &d.true_ranks)?,
            density: num_or("density", d.density)?,
            seed: num_or("seed", d.seed as f64)? as u64,
            decomp: str_or("decomp", "tt")?.parse().map_err(DnttError::config)?,
            grid: usize_arr("grid", &d.grid)?,
            eps: num_or("eps", d.eps)?,
            fixed_ranks,
            algo: str_or("algo", &d.algo)?,
            iters: num_or("iters", d.iters as f64)? as usize,
            prune: bool_or("prune", d.prune)?,
            check_error: bool_or("check_error", d.check_error)?,
            trace: bool_or("trace", d.trace)?,
            kernel: str_or("kernel", &d.kernel)?,
            threads_per_rank: num_or("threads_per_rank", d.threads_per_rank as f64)? as usize,
            priority: str_or("priority", "normal")?.parse().map_err(DnttError::config)?,
            tenant: str_or("tenant", &d.tenant)?,
            label,
        })
    }

    /// Build the runnable [`JobConfig`] (validates the spec).
    pub fn to_config(&self) -> Result<JobConfig> {
        let input = match self.input.as_str() {
            "synthetic" => {
                if self.true_ranks.len() + 1 != self.dims.len() {
                    return Err(DnttError::config(format!(
                        "job spec: true_ranks needs {} entries for {} dims",
                        self.dims.len().saturating_sub(1),
                        self.dims.len()
                    )));
                }
                InputSpec::Synthetic(SyntheticTt::new(
                    self.dims.clone(),
                    self.true_ranks.clone(),
                    self.seed,
                ))
            }
            "sparse" => {
                if !(self.density > 0.0 && self.density <= 1.0) {
                    return Err(DnttError::config(format!(
                        "job spec: density must be in (0, 1], got {}",
                        self.density
                    )));
                }
                InputSpec::SyntheticSparse(SyntheticSparse::new(
                    self.dims.clone(),
                    self.density,
                    self.seed,
                ))
            }
            "faces" => InputSpec::Faces(FaceConfig::default()),
            "video" => InputSpec::Video(VideoConfig::default()),
            "file" => {
                let dir = self.file.as_ref().ok_or_else(|| {
                    DnttError::config("job spec: input 'file' needs a 'file' chunk-set path")
                })?;
                InputSpec::from_chunks(dir)?
            }
            other => {
                return Err(DnttError::config(format!(
                    "job spec: unknown input '{other}' (synthetic|sparse|faces|video|file)"
                )))
            }
        };
        let grid = ProcGrid::new(self.grid.clone())?;
        let algo: NmfAlgo = self.algo.parse().map_err(DnttError::config)?;
        let nmf = NmfConfig { max_iters: self.iters, algo, seed: self.seed, ..Default::default() };
        Ok(JobConfig {
            decomp: self.decomp,
            tt: TtConfig {
                eps: self.eps,
                fixed_ranks: self.fixed_ranks.clone(),
                nmf: nmf.clone(),
                prune: self.prune,
                ..Default::default()
            },
            ht: HtConfig {
                eps: self.eps,
                fixed_ranks: self.fixed_ranks.clone(),
                nmf,
                prune: self.prune,
                ..Default::default()
            },
            check_error: self.check_error,
            trace: self.trace.then(crate::obs::TraceConfig::default),
            kernel: self.kernel.parse().map_err(DnttError::config)?,
            threads_per_rank: self.threads_per_rank.max(1),
            budget: (self.budget_mb > 0).then(|| self.budget_mb * (1 << 20)),
            ..JobConfig::new(input, grid)
        })
    }

    /// Build the full server submission (config + scheduling envelope).
    pub fn to_request(&self) -> Result<JobRequest> {
        let job = self.to_config()?;
        let mut req = JobRequest::new(job).priority(self.priority).tenant(self.tenant.clone());
        if let Some(l) = &self.label {
            req = req.label(l.clone());
        }
        Ok(req)
    }
}

/// One entry of [`Spool::pending`].
pub struct PendingJob {
    pub seq: u64,
    pub spec: JobSpec,
    pub path: PathBuf,
}

/// The on-disk queue directory (see the module docs for layout).
pub struct Spool {
    dir: PathBuf,
}

impl Spool {
    /// Open (creating if needed) a spool rooted at `dir`.
    pub fn open(dir: impl Into<PathBuf>) -> Result<Spool> {
        let dir = dir.into();
        fs::create_dir_all(dir.join("pending"))?;
        fs::create_dir_all(dir.join("done"))?;
        Ok(Spool { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn pending_dir(&self) -> PathBuf {
        self.dir.join("pending")
    }

    pub fn done_dir(&self) -> PathBuf {
        self.dir.join("done")
    }

    fn seq_of(name: &str) -> Option<u64> {
        let rest = name.strip_prefix("job")?;
        let digits = rest.strip_suffix(".json")?;
        if digits.is_empty() || !digits.bytes().all(|b| b.is_ascii_digit()) {
            return None;
        }
        digits.parse().ok()
    }

    fn seqs_in(dir: &Path) -> Vec<u64> {
        match fs::read_dir(dir) {
            Ok(rd) => rd
                .filter_map(|e| e.ok())
                .filter_map(|e| e.file_name().into_string().ok())
                .filter_map(|n| Self::seq_of(&n))
                .collect(),
            Err(_) => Vec::new(),
        }
    }

    /// Append a spec; returns its sequence number. Sequence numbers are
    /// reserved with `create_new`, so concurrent submitters get distinct
    /// files.
    pub fn submit(&self, spec: &JobSpec) -> Result<u64> {
        let body = spec.to_json().to_pretty();
        let mut seq = [Self::seqs_in(&self.pending_dir()), Self::seqs_in(&self.done_dir())]
            .concat()
            .into_iter()
            .max()
            .map_or(0, |m| m + 1);
        loop {
            let path = self.pending_dir().join(format!("job{seq:06}.json"));
            match fs::OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(mut f) => {
                    f.write_all(body.as_bytes())?;
                    return Ok(seq);
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => seq += 1,
                Err(e) => return Err(e.into()),
            }
        }
    }

    /// All pending specs, sorted by sequence number (submission order).
    /// A torn/unparseable file is an error naming its path (runbook:
    /// inspect and delete it).
    pub fn pending(&self) -> Result<Vec<PendingJob>> {
        let dir = self.pending_dir();
        let mut seqs = Self::seqs_in(&dir);
        seqs.sort_unstable();
        let mut out = Vec::with_capacity(seqs.len());
        for seq in seqs {
            let path = dir.join(format!("job{seq:06}.json"));
            let body = fs::read_to_string(&path)?;
            let j = Json::parse(&body)
                .map_err(|e| DnttError::config(format!("{path:?}: {e}")))?;
            let spec = JobSpec::from_json(&j)
                .map_err(|e| DnttError::config(format!("{path:?}: {e}")))?;
            out.push(PendingJob { seq, spec, path });
        }
        Ok(out)
    }

    /// Resolve a pending entry: record its outcome row and move the spec
    /// to `done/`.
    pub fn mark_done(&self, seq: u64, outcome: &Json) -> Result<()> {
        let name = format!("job{seq:06}.json");
        let out_path = self.done_dir().join(format!("job{seq:06}.outcome.json"));
        let tmp = self.done_dir().join(format!("job{seq:06}.outcome.json.tmp"));
        fs::write(&tmp, outcome.to_pretty())?;
        fs::rename(&tmp, &out_path)?;
        let pending = self.pending_dir().join(&name);
        if pending.exists() {
            fs::rename(&pending, self.done_dir().join(&name))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_spool(tag: &str) -> Spool {
        let dir = std::env::temp_dir()
            .join(format!("dntt-spool-test-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        Spool::open(dir).unwrap()
    }

    #[test]
    fn spec_json_roundtrip() {
        let spec = JobSpec {
            input: "sparse".into(),
            density: 0.05,
            fixed_ranks: Some(vec![3, 3, 3]),
            priority: Priority::High,
            tenant: "teamA".into(),
            label: Some("nightly".into()),
            trace: true,
            ..JobSpec::default()
        };
        let j = spec.to_json();
        let back = JobSpec::from_json(&j).unwrap();
        assert_eq!(spec, back);
        // And the JSON itself roundtrips through the parser.
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn spec_defaults_fill_missing_fields() {
        let j = Json::parse(&format!(r#"{{"format":"{JOB_FORMAT}","seed":7}}"#)).unwrap();
        let spec = JobSpec::from_json(&j).unwrap();
        assert_eq!(spec.seed, 7);
        assert_eq!(spec.dims, vec![16, 16, 16, 16]);
        assert_eq!(spec.priority, Priority::Normal);
        let cfg = spec.to_config().unwrap();
        assert_eq!(cfg.grid.size(), 1);
    }

    #[test]
    fn bad_specs_rejected() {
        assert!(JobSpec::from_json(&Json::parse(r#"{"input":"synthetic"}"#).unwrap()).is_err());
        let spec = JobSpec { true_ranks: vec![4], ..JobSpec::default() };
        assert!(spec.to_config().is_err(), "wrong true_ranks arity");
        let spec = JobSpec { input: "sparse".into(), density: 0.0, ..JobSpec::default() };
        assert!(spec.to_config().is_err(), "density out of range");
        let spec = JobSpec { input: "nope".into(), ..JobSpec::default() };
        assert!(spec.to_config().is_err(), "unknown input kind");
    }

    #[test]
    fn smoke_spec_matches_decompose_smoke_fingerprint() {
        // The served smoke job must hit the same cache entry as a solo
        // `decompose --smoke` with identical knobs.
        let spec = JobSpec::smoke(42);
        let cfg = spec.to_config().unwrap();
        assert_eq!(cfg.input.dims(), vec![8, 8, 8, 8]);
        assert_eq!(cfg.grid.dims(), &[2, 2, 1, 1]);
        let again = JobSpec::smoke(42).to_config().unwrap();
        assert_eq!(cfg.fingerprint(), again.fingerprint());
        assert_ne!(cfg.fingerprint(), JobSpec::smoke(43).to_config().unwrap().fingerprint());
    }

    #[test]
    fn spool_submit_pending_done_cycle() {
        let spool = temp_spool("cycle");
        let s0 = spool.submit(&JobSpec::smoke(1)).unwrap();
        let s1 = spool.submit(&JobSpec::smoke(2)).unwrap();
        assert!(s1 > s0);
        let pending = spool.pending().unwrap();
        assert_eq!(pending.len(), 2);
        assert_eq!(pending[0].seq, s0);
        assert_eq!(pending[1].spec.seed, 2);
        spool
            .mark_done(s0, &Json::obj(vec![("ok", Json::Bool(true))]))
            .unwrap();
        let pending = spool.pending().unwrap();
        assert_eq!(pending.len(), 1);
        assert_eq!(pending[0].seq, s1);
        // Sequence numbers never reuse a done slot.
        let s2 = spool.submit(&JobSpec::smoke(3)).unwrap();
        assert!(s2 > s1);
        let _ = fs::remove_dir_all(spool.dir());
    }
}
