//! Tucker decomposition via HOSVD + HOOI — the Fig-2 "Tucker" baseline.
//!
//! HOSVD initializes each factor with the leading eigenvectors of the
//! mode-`k` unfolding's Gram matrix (`n_k × n_k`, small); HOOI then
//! alternates, recomputing each factor against the partially-projected
//! tensor. Ranks come from the same ε-threshold heuristic as the TT path
//! (per-mode, with the threshold split as `ε/√d`) or can be fixed.

use crate::error::Result;
use crate::linalg::eig::sym_eig;
use crate::linalg::gemm::gram_m_mt;
use crate::linalg::svd::rank_for_eps;
use crate::linalg::Mat;
use crate::tensor::{DenseTensor, Tucker};

/// Tucker with ε-threshold per-mode rank selection.
pub fn tucker_hooi(tensor: &DenseTensor<f64>, eps: f64, sweeps: usize) -> Result<Tucker<f64>> {
    let per_mode = eps / (tensor.ndim() as f64).sqrt();
    let ranks: Vec<usize> = (0..tensor.ndim())
        .map(|k| {
            let unf = tensor.unfold_mode(k);
            let sig = gram_singular_values(&unf);
            rank_for_eps(&sig, per_mode)
        })
        .collect();
    tucker_hooi_fixed(tensor, &ranks, sweeps)
}

/// Tucker with fixed multilinear ranks.
pub fn tucker_hooi_fixed(
    tensor: &DenseTensor<f64>,
    ranks: &[usize],
    sweeps: usize,
) -> Result<Tucker<f64>> {
    let d = tensor.ndim();
    assert_eq!(ranks.len(), d);
    // HOSVD init.
    let mut factors: Vec<Mat<f64>> = (0..d)
        .map(|k| {
            let unf = tensor.unfold_mode(k);
            leading_eigvecs(&unf, ranks[k].min(tensor.dims()[k]))
        })
        .collect();
    // HOOI sweeps.
    for _ in 0..sweeps {
        for k in 0..d {
            // Project all modes except k.
            let mut proj = tensor.clone();
            for (m, f) in factors.iter().enumerate() {
                if m != k {
                    proj = proj.mode_product(m, &f.transpose());
                }
            }
            let unf = proj.unfold_mode(k);
            factors[k] = leading_eigvecs(&unf, ranks[k].min(tensor.dims()[k]));
        }
    }
    // Core = A ×₁ U₁ᵀ … ×_d U_dᵀ.
    let mut core = tensor.clone();
    for (m, f) in factors.iter().enumerate() {
        core = core.mode_product(m, &f.transpose());
    }
    Tucker::new(core, factors)
}

/// Singular values of `unf` via the small-side Gram.
fn gram_singular_values(unf: &Mat<f64>) -> Vec<f64> {
    let g = gram_m_mt(unf); // rows are the mode dim (small side)
    sym_eig(&g).values.into_iter().map(|l| l.max(0.0).sqrt()).collect()
}

/// Leading `r` eigenvectors of `unf·unfᵀ` as an `n_k × r` factor.
fn leading_eigvecs(unf: &Mat<f64>, r: usize) -> Mat<f64> {
    let g = gram_m_mt(unf);
    let e = sym_eig(&g);
    e.vectors.cols_slice(0, r.min(e.vectors.cols()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn low_multilinear(dims: &[usize], ranks: &[usize], seed: u64) -> DenseTensor<f64> {
        let mut rng = Rng::new(seed);
        let core = DenseTensor::<f64>::rand_uniform(ranks, &mut rng);
        let factors: Vec<Mat<f64>> =
            dims.iter().zip(ranks).map(|(&n, &r)| Mat::rand_uniform(n, r, &mut rng)).collect();
        Tucker::new(core, factors).unwrap().reconstruct()
    }

    #[test]
    fn exact_recovery_at_true_ranks() {
        let t = low_multilinear(&[6, 7, 5], &[2, 3, 2], 1);
        let tk = tucker_hooi_fixed(&t, &[2, 3, 2], 2).unwrap();
        let err = t.rel_error(&tk.reconstruct());
        assert!(err < 1e-9, "err={err}");
    }

    #[test]
    fn eps_rank_selection_finds_true_ranks() {
        let t = low_multilinear(&[6, 6, 6], &[2, 2, 3], 2);
        let tk = tucker_hooi(&t, 1e-6, 2).unwrap();
        assert_eq!(tk.ranks(), &[2, 2, 3]);
    }

    #[test]
    fn truncation_reduces_params_increases_error() {
        let mut rng = Rng::new(3);
        let t = DenseTensor::<f64>::rand_uniform(&[6, 6, 6], &mut rng);
        let full = tucker_hooi_fixed(&t, &[6, 6, 6], 1).unwrap();
        let trunc = tucker_hooi_fixed(&t, &[3, 3, 3], 2).unwrap();
        assert!(trunc.num_params() < full.num_params());
        assert!(t.rel_error(&full.reconstruct()) < 1e-9);
        assert!(t.rel_error(&trunc.reconstruct()) > 1e-3);
    }

    #[test]
    fn hooi_improves_or_matches_hosvd() {
        let mut rng = Rng::new(4);
        let t = DenseTensor::<f64>::rand_uniform(&[5, 6, 7], &mut rng);
        let hosvd = tucker_hooi_fixed(&t, &[2, 2, 2], 0).unwrap();
        let hooi = tucker_hooi_fixed(&t, &[2, 2, 2], 3).unwrap();
        let e0 = t.rel_error(&hosvd.reconstruct());
        let e1 = t.rel_error(&hooi.reconstruct());
        assert!(e1 <= e0 + 1e-10, "HOOI {e1} worse than HOSVD {e0}");
    }
}
