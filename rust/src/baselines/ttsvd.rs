//! Sequential TT-SVD (Oseledets) — the unconstrained baseline.
//!
//! The paper's Figs 2, 8 and 9 compare nTT against the classical SVD-based
//! tensor train ("TT"/"SVD-TT"). This is the standard sweep: left-unfold,
//! thin SVD, truncate by the same ε-threshold heuristic (or fixed ranks),
//! keep `U` as the core, continue with `diag(σ)·Vᵀ`. Cores may be negative.

use crate::error::Result;
use crate::linalg::svd::{rank_for_eps, thin_svd};
use crate::linalg::Mat;
use crate::tensor::{DenseTensor, TTensor};

/// TT-SVD with per-stage ε-threshold rank selection.
pub fn tt_svd(tensor: &DenseTensor<f64>, eps: f64) -> Result<TTensor<f64>> {
    tt_svd_impl(tensor, RankRule::Eps(eps))
}

/// TT-SVD with fixed TT ranks (length `d-1`).
pub fn tt_svd_fixed(tensor: &DenseTensor<f64>, ranks: &[usize]) -> Result<TTensor<f64>> {
    tt_svd_impl(tensor, RankRule::Fixed(ranks.to_vec()))
}

enum RankRule {
    Eps(f64),
    Fixed(Vec<usize>),
}

fn tt_svd_impl(tensor: &DenseTensor<f64>, rule: RankRule) -> Result<TTensor<f64>> {
    let dims = tensor.dims().to_vec();
    let d = dims.len();
    let mut cores: Vec<Mat<f64>> = Vec::with_capacity(d);
    let mut r_prev = 1usize;
    let mut rest: usize = dims.iter().product();
    // Current remainder as an (r_prev × rest) matrix, row-major.
    let mut cur = Mat::from_vec(1, rest, tensor.as_slice().to_vec());

    for l in 0..d - 1 {
        let n_l = dims[l];
        let m = r_prev * n_l;
        rest /= n_l;
        let x = cur.reshaped(m, rest);
        let svd = thin_svd(&x);
        let rank = match &rule {
            RankRule::Eps(eps) => rank_for_eps(&svd.s, *eps),
            RankRule::Fixed(rs) => rs[l].clamp(1, svd.s.len().max(1)),
        };
        let tr = svd.truncate(rank);
        cores.push(tr.u.clone());
        // Remainder = diag(σ)·Vᵀ (rank × rest).
        let mut sv = tr.vt.clone();
        for c in 0..rank {
            let s = tr.s[c];
            for v in sv.row_mut(c) {
                *v *= s;
            }
        }
        cur = sv;
        r_prev = rank;
    }
    cores.push(cur.reshaped(r_prev * dims[d - 1], 1));
    TTensor::new(dims, cores)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ttrain::datagen::SyntheticTt;
    use crate::util::rng::Rng;

    #[test]
    fn exact_recovery_of_tt_tensor() {
        let syn = SyntheticTt::new(vec![4, 5, 6], vec![2, 3], 1);
        let t = syn.dense();
        let tt = tt_svd(&t, 1e-10).unwrap();
        assert_eq!(tt.ranks(), &[1, 2, 3, 1]);
        assert!(tt.rel_error(&t) < 1e-9);
    }

    #[test]
    fn eps_controls_error() {
        let mut rng = Rng::new(2);
        let t = DenseTensor::<f64>::rand_uniform(&[6, 6, 6, 6], &mut rng);
        for eps in [0.5, 0.1, 0.01] {
            let tt = tt_svd(&t, eps).unwrap();
            // Per-stage eps: total error ≤ sqrt(d-1)·eps (Oseledets Thm 2.2).
            let bound = eps * ((t.ndim() - 1) as f64).sqrt() + 1e-12;
            let err = tt.rel_error(&t);
            assert!(err <= bound, "eps={eps}: err {err} > bound {bound}");
        }
    }

    #[test]
    fn tighter_eps_larger_ranks() {
        let mut rng = Rng::new(3);
        let t = DenseTensor::<f64>::rand_uniform(&[5, 5, 5], &mut rng);
        let loose = tt_svd(&t, 0.3).unwrap();
        let tight = tt_svd(&t, 1e-6).unwrap();
        assert!(tight.num_params() >= loose.num_params());
        assert!(tight.rel_error(&t) <= loose.rel_error(&t) + 1e-12);
    }

    #[test]
    fn fixed_ranks_respected() {
        let mut rng = Rng::new(4);
        let t = DenseTensor::<f64>::rand_uniform(&[4, 4, 4], &mut rng);
        let tt = tt_svd_fixed(&t, &[2, 3]).unwrap();
        assert_eq!(tt.ranks(), &[1, 2, 3, 1]);
    }

    #[test]
    fn full_rank_is_exact() {
        let mut rng = Rng::new(5);
        let t = DenseTensor::<f64>::rand_uniform(&[3, 4, 3], &mut rng);
        let tt = tt_svd(&t, 0.0).unwrap();
        assert!(tt.rel_error(&t) < 1e-9);
    }
}
