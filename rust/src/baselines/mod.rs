//! Baseline decompositions the paper compares against (Fig 2, Fig 8,
//! Fig 9): classical TT-SVD, Tucker via HOSVD/HOOI, and non-negative
//! Tucker via multiplicative updates.

pub mod ntucker;
pub mod ttsvd;
pub mod tucker_hooi;

pub use ntucker::{ntucker_eps, ntucker_mu};
pub use ttsvd::{tt_svd, tt_svd_fixed};
pub use tucker_hooi::{tucker_hooi, tucker_hooi_fixed};
