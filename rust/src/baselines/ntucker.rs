//! Non-negative Tucker decomposition (multiplicative updates) — the Fig-2
//! "nTucker" baseline.
//!
//! Standard Lee–Seung-style NTD: factors and core stay element-wise
//! non-negative;
//! `U_k ← U_k ⊙ (X_(k) Z_kᵀ) ⊘ (U_k Z_k Z_kᵀ)` with
//! `Z_k = unfold_k(G ×_{j≠k} U_j)`, and
//! `G ← G ⊙ (X ×ⱼ U_jᵀ) ⊘ (G ×ⱼ (U_jᵀU_j))`.

use crate::error::Result;
use crate::linalg::gemm::{gram_mt_m, matmul, matmul_a_bt};
use crate::linalg::Mat;
use crate::tensor::{DenseTensor, Tucker};
use crate::util::rng::Rng;

const EPS: f64 = 1e-16;

/// Non-negative Tucker with fixed multilinear ranks.
pub fn ntucker_mu(
    tensor: &DenseTensor<f64>,
    ranks: &[usize],
    iters: usize,
    seed: u64,
) -> Result<Tucker<f64>> {
    let d = tensor.ndim();
    assert_eq!(ranks.len(), d);
    let mut rng = Rng::new(seed);
    let mut factors: Vec<Mat<f64>> = tensor
        .dims()
        .iter()
        .zip(ranks)
        .map(|(&n, &r)| Mat::rand_uniform(n, r, &mut rng))
        .collect();
    let mut core = DenseTensor::<f64>::rand_uniform(ranks, &mut rng);

    for _ in 0..iters {
        // --- factor updates
        for k in 0..d {
            // Z_k = unfold_k(core ×_{j≠k} U_j): shape r_k × (Π_{j≠k} n_j)
            let mut z = core.clone();
            for (j, f) in factors.iter().enumerate() {
                if j != k {
                    z = z.mode_product(j, f);
                }
            }
            let zk = z.unfold_mode(k);
            let xk = tensor.unfold_mode(k);
            let num = matmul_a_bt(&xk, &zk); // n_k × r_k
            let zzt = matmul_a_bt(&zk, &zk); // r_k × r_k
            let den = matmul(&factors[k], &zzt); // n_k × r_k
            let f = &mut factors[k];
            for (v, (nu, de)) in
                f.as_mut_slice().iter_mut().zip(num.as_slice().iter().zip(den.as_slice()))
            {
                *v *= nu / (de + EPS);
            }
        }
        // --- core update
        // numerator: X ×ⱼ U_jᵀ; denominator: G ×ⱼ (U_jᵀ U_j).
        let mut num = tensor.clone();
        let mut den = core.clone();
        for (j, f) in factors.iter().enumerate() {
            num = num.mode_product(j, &f.transpose());
            den = den.mode_product(j, &gram_mt_m(f));
        }
        for (g, (nu, de)) in core
            .as_mut_slice()
            .iter_mut()
            .zip(num.as_slice().iter().zip(den.as_slice()))
        {
            *g *= nu / (de + EPS);
        }
    }
    Tucker::new(core, factors)
}

/// ε-threshold variant: pick per-mode ranks with the Tucker heuristic, then
/// run NTD at those ranks.
pub fn ntucker_eps(
    tensor: &DenseTensor<f64>,
    eps: f64,
    iters: usize,
    seed: u64,
) -> Result<Tucker<f64>> {
    use crate::linalg::eig::sym_eig;
    use crate::linalg::gemm::gram_m_mt;
    use crate::linalg::svd::rank_for_eps;
    let per_mode = eps / (tensor.ndim() as f64).sqrt();
    let ranks: Vec<usize> = (0..tensor.ndim())
        .map(|k| {
            let unf = tensor.unfold_mode(k);
            let sig: Vec<f64> =
                sym_eig(&gram_m_mt(&unf)).values.into_iter().map(|l| l.max(0.0).sqrt()).collect();
            rank_for_eps(&sig, per_mode)
        })
        .collect();
    ntucker_mu(tensor, &ranks, iters, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nonneg_tucker_tensor(dims: &[usize], ranks: &[usize], seed: u64) -> DenseTensor<f64> {
        let mut rng = Rng::new(seed);
        let core = DenseTensor::<f64>::rand_uniform(ranks, &mut rng);
        let factors: Vec<Mat<f64>> =
            dims.iter().zip(ranks).map(|(&n, &r)| Mat::rand_uniform(n, r, &mut rng)).collect();
        Tucker::new(core, factors).unwrap().reconstruct()
    }

    #[test]
    fn converges_on_nonneg_tucker_data() {
        let t = nonneg_tucker_tensor(&[6, 5, 4], &[2, 2, 2], 1);
        let td = ntucker_mu(&t, &[2, 2, 2], 300, 7).unwrap();
        assert!(td.is_nonneg());
        let err = t.rel_error(&td.reconstruct());
        assert!(err < 0.05, "err={err}");
    }

    #[test]
    fn objective_decreases() {
        let t = nonneg_tucker_tensor(&[5, 5, 5], &[2, 2, 2], 2);
        let e10 = t.rel_error(&ntucker_mu(&t, &[2, 2, 2], 10, 3).unwrap().reconstruct());
        let e100 = t.rel_error(&ntucker_mu(&t, &[2, 2, 2], 100, 3).unwrap().reconstruct());
        assert!(e100 <= e10 + 1e-9, "{e100} vs {e10}");
    }

    #[test]
    fn eps_variant_runs() {
        let t = nonneg_tucker_tensor(&[5, 4, 4], &[2, 2, 2], 4);
        let td = ntucker_eps(&t, 1e-6, 50, 5).unwrap();
        assert_eq!(td.ranks(), &[2, 2, 2]);
        assert!(td.is_nonneg());
    }
}
