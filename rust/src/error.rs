//! Error type for the dntt library.
//!
//! Hand-rolled `Display`/`Error` impls rather than `thiserror` — the
//! offline build environment has no access to proc-macro crates (see
//! DESIGN.md §4, Substitutions).

use std::fmt;

/// Library-level error.
#[derive(Debug)]
pub enum DnttError {
    /// Dimension / shape mismatch.
    Shape(String),
    /// Invalid configuration or arguments.
    Config(String),
    /// Communicator / collective misuse.
    Comm(String),
    /// A rank died mid-collective (detected via the poison machinery;
    /// deterministic under `dist::faults` injection). The job may be
    /// resumable from its last durable checkpoint (`--resume auto`).
    RankLost {
        /// World rank that died.
        rank: usize,
        /// 1-based collective count on that rank at the time of death.
        op: u64,
    },
    /// AOT artifact problems (missing manifest entries, bad files).
    Artifact(String),
    /// Underlying I/O failure.
    Io(std::io::Error),
    /// XLA / PJRT runtime failure.
    Xla(String),
    /// Anything else.
    Other(String),
}

impl fmt::Display for DnttError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DnttError::Shape(m) => write!(f, "shape error: {m}"),
            DnttError::Config(m) => write!(f, "config error: {m}"),
            DnttError::Comm(m) => write!(f, "communicator error: {m}"),
            DnttError::RankLost { rank, op } => {
                write!(f, "rank lost: rank {rank} died at collective #{op}")
            }
            DnttError::Artifact(m) => write!(f, "artifact error: {m}"),
            DnttError::Io(e) => write!(f, "io error: {e}"),
            DnttError::Xla(m) => write!(f, "xla error: {m}"),
            DnttError::Other(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for DnttError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            DnttError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for DnttError {
    fn from(e: std::io::Error) -> Self {
        DnttError::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, DnttError>;

impl From<crate::util::json::JsonError> for DnttError {
    fn from(e: crate::util::json::JsonError) -> Self {
        DnttError::Config(e.to_string())
    }
}

/// Shorthand constructors.
impl DnttError {
    pub fn shape(msg: impl Into<String>) -> Self {
        DnttError::Shape(msg.into())
    }
    pub fn config(msg: impl Into<String>) -> Self {
        DnttError::Config(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes() {
        assert_eq!(DnttError::shape("bad").to_string(), "shape error: bad");
        assert_eq!(DnttError::config("bad").to_string(), "config error: bad");
        assert_eq!(DnttError::Comm("x".into()).to_string(), "communicator error: x");
        assert_eq!(
            DnttError::RankLost { rank: 3, op: 7 }.to_string(),
            "rank lost: rank 3 died at collective #7"
        );
        assert_eq!(DnttError::Other("plain".into()).to_string(), "plain");
    }

    #[test]
    fn io_conversion_keeps_source() {
        use std::error::Error;
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: DnttError = io.into();
        assert!(e.to_string().contains("gone"));
        assert!(e.source().is_some());
    }
}
