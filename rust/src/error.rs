//! Error type for the dntt library.

use thiserror::Error;

/// Library-level error.
#[derive(Error, Debug)]
pub enum DnttError {
    #[error("shape error: {0}")]
    Shape(String),
    #[error("config error: {0}")]
    Config(String),
    #[error("communicator error: {0}")]
    Comm(String),
    #[error("artifact error: {0}")]
    Artifact(String),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("xla error: {0}")]
    Xla(String),
    #[error("{0}")]
    Other(String),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, DnttError>;

impl From<crate::util::json::JsonError> for DnttError {
    fn from(e: crate::util::json::JsonError) -> Self {
        DnttError::Config(e.to_string())
    }
}

/// Shorthand constructors.
impl DnttError {
    pub fn shape(msg: impl Into<String>) -> Self {
        DnttError::Shape(msg.into())
    }
    pub fn config(msg: impl Into<String>) -> Self {
        DnttError::Config(msg.into())
    }
}
