//! Structural similarity index (SSIM) — the Fig-9 denoising metric.
//!
//! Standard Wang et al. SSIM with an 8×8 sliding window (uniform weights)
//! and the usual stabilizers `C1 = (0.01·L)²`, `C2 = (0.03·L)²` where `L`
//! is the dynamic range. Computed per image and averaged over windows.

/// SSIM between two images given as row-major `h×w` slices.
/// `dynamic_range` is `L` (e.g. 255 for 8-bit, or the data max).
pub fn ssim(a: &[f64], b: &[f64], h: usize, w: usize, dynamic_range: f64) -> f64 {
    assert_eq!(a.len(), h * w);
    assert_eq!(b.len(), h * w);
    let win = 8usize.min(h).min(w);
    if win == 0 {
        return 1.0;
    }
    let c1 = (0.01 * dynamic_range).powi(2);
    let c2 = (0.03 * dynamic_range).powi(2);
    let mut total = 0.0;
    let mut count = 0usize;
    let step = 1usize;
    let nw = win * win;
    for y0 in (0..=h - win).step_by(step) {
        for x0 in (0..=w - win).step_by(step) {
            let (mut ma, mut mb) = (0.0, 0.0);
            for y in y0..y0 + win {
                for x in x0..x0 + win {
                    ma += a[y * w + x];
                    mb += b[y * w + x];
                }
            }
            ma /= nw as f64;
            mb /= nw as f64;
            let (mut va, mut vb, mut cov) = (0.0, 0.0, 0.0);
            for y in y0..y0 + win {
                for x in x0..x0 + win {
                    let da = a[y * w + x] - ma;
                    let db = b[y * w + x] - mb;
                    va += da * da;
                    vb += db * db;
                    cov += da * db;
                }
            }
            va /= (nw - 1) as f64;
            vb /= (nw - 1) as f64;
            cov /= (nw - 1) as f64;
            let s = ((2.0 * ma * mb + c1) * (2.0 * cov + c2))
                / ((ma * ma + mb * mb + c1) * (va + vb + c2));
            total += s;
            count += 1;
        }
    }
    total / count as f64
}

/// Mean SSIM over the leading two modes of a 4-D tensor (each `[:, :, i, j]`
/// slice is an image) — the Fig-9 aggregation for the Yale tensor.
pub fn mean_ssim_images(
    a: &crate::tensor::DenseTensor<f64>,
    b: &crate::tensor::DenseTensor<f64>,
) -> f64 {
    assert_eq!(a.dims(), b.dims());
    assert!(a.ndim() >= 2);
    let dims = a.dims();
    let (h, w) = (dims[0], dims[1]);
    let rest: usize = dims[2..].iter().product();
    let peak = a.as_slice().iter().cloned().fold(0.0f64, f64::max).max(1e-12);
    let mut total = 0.0;
    // Extract image (h×w) for each trailing index combo.
    let mut img_a = vec![0.0; h * w];
    let mut img_b = vec![0.0; h * w];
    for t in 0..rest {
        for y in 0..h {
            for x in 0..w {
                let idx = (y * w + x) * rest + t;
                img_a[y * w + x] = a.as_slice()[idx];
                img_b[y * w + x] = b.as_slice()[idx];
            }
        }
        total += ssim(&img_a, &img_b, h, w, peak);
    }
    total / rest as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DenseTensor;
    use crate::util::rng::Rng;

    #[test]
    fn identical_images_score_one() {
        let mut rng = Rng::new(1);
        let img: Vec<f64> = (0..256).map(|_| rng.uniform()).collect();
        let s = ssim(&img, &img, 16, 16, 1.0);
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn noise_lowers_ssim() {
        let mut rng = Rng::new(2);
        // A structured image, not pure noise.
        let img: Vec<f64> =
            (0..400).map(|i| ((i / 20) as f64 * 0.3).sin().abs() + 0.2).collect();
        let noisy: Vec<f64> = img.iter().map(|&v| (v + rng.normal_ms(0.0, 0.3)).max(0.0)).collect();
        let very_noisy: Vec<f64> =
            img.iter().map(|&v| (v + rng.normal_ms(0.0, 1.0)).max(0.0)).collect();
        let s1 = ssim(&img, &noisy, 20, 20, 1.4);
        let s2 = ssim(&img, &very_noisy, 20, 20, 1.4);
        assert!(s1 < 1.0);
        assert!(s2 < s1, "{s2} !< {s1}");
    }

    #[test]
    fn symmetric() {
        let mut rng = Rng::new(3);
        let a: Vec<f64> = (0..144).map(|_| rng.uniform()).collect();
        let b: Vec<f64> = (0..144).map(|_| rng.uniform()).collect();
        let s1 = ssim(&a, &b, 12, 12, 1.0);
        let s2 = ssim(&b, &a, 12, 12, 1.0);
        assert!((s1 - s2).abs() < 1e-12);
    }

    #[test]
    fn tensor_mean_ssim() {
        let mut rng = Rng::new(4);
        let t = DenseTensor::<f64>::rand_uniform(&[12, 12, 2, 3], &mut rng);
        assert!((mean_ssim_images(&t, &t) - 1.0).abs() < 1e-12);
        let noisy = crate::data::noise::add_gaussian_noise(&t, 0.5, 5);
        assert!(mean_ssim_images(&t, &noisy) < 0.9);
    }
}
