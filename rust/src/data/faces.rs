//! Synthetic illumination-cone face dataset (Extended Yale B substitute).
//!
//! The real Yale B dataset (38 subjects × 64 illuminations, images
//! down-sampled to 48×42 in the paper) is not redistributable here, so the
//! experiment uses a generative model with the same statistical structure
//! the TT/nTT experiments exploit: each subject is a smooth non-negative
//! "identity" image (mixture of Gaussian blobs: eyes/nose/mouth/face
//! contour), and each illumination condition is a low-dimensional lighting
//! field (lambertian-style directional shading + ambient). The resulting
//! 4-D tensor `height × width × illumination × subject` is non-negative
//! and approximately low-TT-rank along the illumination and subject modes
//! — the properties Figs 8a and 9 measure.

use crate::tensor::DenseTensor;
use crate::util::rng::Rng;

/// Dataset dimensions (defaults match the paper: 48×42×64×38).
#[derive(Clone, Debug)]
pub struct FaceConfig {
    pub height: usize,
    pub width: usize,
    pub illuminations: usize,
    pub subjects: usize,
    pub seed: u64,
}

impl Default for FaceConfig {
    fn default() -> Self {
        FaceConfig { height: 48, width: 42, illuminations: 64, subjects: 38, seed: 3435 }
    }
}

/// Generate the face tensor (`height × width × illum × subject`).
pub fn generate_faces(cfg: &FaceConfig) -> DenseTensor<f64> {
    let mut rng = Rng::new(cfg.seed);
    let (h, w) = (cfg.height, cfg.width);

    // Per-subject identity images.
    let mut identities: Vec<Vec<f64>> = Vec::with_capacity(cfg.subjects);
    for _ in 0..cfg.subjects {
        identities.push(identity_image(h, w, &mut rng));
    }
    // Per-illumination lighting fields: direction + ambient level.
    let mut lights: Vec<Vec<f64>> = Vec::with_capacity(cfg.illuminations);
    for li in 0..cfg.illuminations {
        lights.push(light_field(h, w, li, cfg.illuminations, &mut rng));
    }

    let mut t = DenseTensor::<f64>::zeros(&[h, w, cfg.illuminations, cfg.subjects]);
    let data = t.as_mut_slice();
    for y in 0..h {
        for x in 0..w {
            let pix = y * w + x;
            for (li, light) in lights.iter().enumerate() {
                let shade = light[pix];
                for (si, ident) in identities.iter().enumerate() {
                    // row-major [y, x, li, si]
                    let idx = ((y * w + x) * cfg.illuminations + li) * cfg.subjects + si;
                    data[idx] = ident[pix] * shade;
                }
            }
        }
    }
    t
}

/// Smooth non-negative "face": elliptical head + features as Gaussian blobs.
fn identity_image(h: usize, w: usize, rng: &mut Rng) -> Vec<f64> {
    let mut img = vec![0.0f64; h * w];
    let (cy, cx) = (h as f64 / 2.0, w as f64 / 2.0);
    let (ry, rx) = (h as f64 * 0.42, w as f64 * 0.38);
    // Feature blobs: two eyes, nose, mouth with per-subject jitter.
    let jitter = |rng: &mut Rng| rng.uniform_range(-0.06, 0.06);
    let feats = [
        (0.38 + jitter(rng), 0.33 + jitter(rng), 0.07, 0.8 + rng.uniform() * 0.4),
        (0.38 + jitter(rng), 0.67 + jitter(rng), 0.07, 0.8 + rng.uniform() * 0.4),
        (0.55 + jitter(rng), 0.50 + jitter(rng), 0.09, 0.5 + rng.uniform() * 0.4),
        (0.72 + jitter(rng), 0.50 + jitter(rng), 0.12, 0.6 + rng.uniform() * 0.5),
    ];
    let skin = 0.45 + rng.uniform() * 0.25;
    for y in 0..h {
        for x in 0..w {
            let dy = (y as f64 - cy) / ry;
            let dx = (x as f64 - cx) / rx;
            let inside = dy * dy + dx * dx;
            let mut v = if inside <= 1.0 { skin * (1.0 - 0.35 * inside) } else { 0.02 };
            for &(fy, fx, fs, fa) in &feats {
                let ddy = y as f64 / h as f64 - fy;
                let ddx = x as f64 / w as f64 - fx;
                v += fa * (-(ddy * ddy + ddx * ddx) / (2.0 * fs * fs)).exp();
            }
            img[y * w + x] = v;
        }
    }
    img
}

/// Directional lambertian-style shading over the image plane + ambient.
fn light_field(h: usize, w: usize, li: usize, total: usize, rng: &mut Rng) -> Vec<f64> {
    // Sweep azimuth/elevation over the illumination index (Yale B's grid),
    // plus small random perturbation.
    let az = -1.2 + 2.4 * (li % 8) as f64 / 7.0 + rng.uniform_range(-0.05, 0.05);
    let el = -0.9 + 1.8 * (li / 8) as f64 / ((total / 8).max(1) as f64) + rng.uniform_range(-0.05, 0.05);
    let ambient = 0.15 + 0.1 * rng.uniform();
    let mut f = vec![0.0f64; h * w];
    for y in 0..h {
        for x in 0..w {
            let ny = 2.0 * (y as f64 / h as f64) - 1.0;
            let nx = 2.0 * (x as f64 / w as f64) - 1.0;
            // Surface normal of a sphere-ish face: (nx, ny, sqrt(1-...)).
            let nz = (1.0 - 0.5 * (nx * nx + ny * ny)).max(0.0).sqrt();
            let dot = (-az * nx - el * ny + nz) / (1.0 + az * az + el * el).sqrt();
            f[y * w + x] = ambient + dot.max(0.0);
        }
    }
    f
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_dims_match_paper() {
        let cfg = FaceConfig { illuminations: 8, subjects: 4, ..Default::default() };
        let t = generate_faces(&cfg);
        assert_eq!(t.dims(), &[48, 42, 8, 4]);
    }

    #[test]
    fn nonnegative_and_nontrivial() {
        let cfg = FaceConfig { height: 24, width: 21, illuminations: 8, subjects: 5, seed: 1 };
        let t = generate_faces(&cfg);
        assert!(t.is_nonneg());
        assert!(t.fro_norm() > 0.0);
        // Values vary (not constant).
        let mx = t.as_slice().iter().cloned().fold(0.0f64, f64::max);
        let mn = t.as_slice().iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(mx > mn + 0.1);
    }

    #[test]
    fn low_rank_structure_present() {
        // The illumination×subject structure must be much lower rank than
        // a random tensor: compare TT-SVD compression at 10% error.
        let cfg = FaceConfig { height: 16, width: 14, illuminations: 8, subjects: 6, seed: 2 };
        let t = generate_faces(&cfg);
        let tt = crate::baselines::ttsvd::tt_svd(&t, 0.1).unwrap();
        assert!(
            tt.compression_ratio() > 3.0,
            "faces should compress well, got {}",
            tt.compression_ratio()
        );
    }

    #[test]
    fn deterministic() {
        let cfg = FaceConfig { height: 8, width: 8, illuminations: 4, subjects: 3, seed: 5 };
        assert_eq!(generate_faces(&cfg).as_slice(), generate_faces(&cfg).as_slice());
    }
}
