//! Synthetic high-speed-video tensor (gun-shot video substitute).
//!
//! The paper's video tensor (100×260×3×85: monochrome image × RGB channel
//! × frame) comes from a YouTube high-speed recording of a pistol shot.
//! The substitute renders the same *kind* of scene synthetically: a static
//! background, a translating projectile, a muzzle flash decaying over
//! frames and an expanding smoke plume — smooth temporal structure with a
//! sharp transient, non-negative by construction.

use crate::tensor::DenseTensor;
use crate::util::rng::Rng;

/// Video dimensions (defaults match the paper: 100×260×3×85).
#[derive(Clone, Debug)]
pub struct VideoConfig {
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    pub frames: usize,
    pub seed: u64,
}

impl Default for VideoConfig {
    fn default() -> Self {
        VideoConfig { height: 100, width: 260, channels: 3, frames: 85, seed: 73000 }
    }
}

/// Generate the video tensor (`height × width × channel × frame`).
pub fn generate_video(cfg: &VideoConfig) -> DenseTensor<f64> {
    let mut rng = Rng::new(cfg.seed);
    let (h, w, c, f) = (cfg.height, cfg.width, cfg.channels, cfg.frames);

    // Static background: smooth horizontal gradient + fixed texture.
    let bg: Vec<f64> = (0..h * w)
        .map(|p| {
            let (y, x) = (p / w, p % w);
            0.25 + 0.1 * (x as f64 / w as f64) + 0.05 * ((y as f64 * 0.31).sin().abs())
        })
        .collect();
    // Per-channel tint of flash/smoke (flash is warm, smoke is grey).
    let flash_tint: Vec<f64> = (0..c).map(|ch| 1.0 - 0.25 * ch as f64 / c.max(1) as f64).collect();
    let smoke_tint: Vec<f64> = (0..c).map(|_| 0.8 + 0.05 * rng.uniform()).collect();

    let muzzle = (h as f64 * 0.5, w as f64 * 0.12);
    let bullet_speed = w as f64 * 0.8 / f as f64;

    let mut t = DenseTensor::<f64>::zeros(&[h, w, c, f]);
    let data = t.as_mut_slice();
    for fr in 0..f {
        let time = fr as f64;
        let bullet_x = muzzle.1 + 8.0 + bullet_speed * time;
        let flash = (-time / 4.0).exp(); // fast decay
        let smoke_r = 4.0 + 1.8 * time; // expanding plume
        let smoke_a = 0.5 * (-time / 40.0).exp();
        for y in 0..h {
            for x in 0..w {
                let pix = y * w + x;
                // Bullet: small bright Gaussian.
                let bdy = y as f64 - muzzle.0;
                let bdx = x as f64 - bullet_x;
                let bullet = 1.2 * (-(bdy * bdy + bdx * bdx) / 8.0).exp();
                // Muzzle flash.
                let fdy = y as f64 - muzzle.0;
                let fdx = x as f64 - muzzle.1;
                let r2 = fdy * fdy + fdx * fdx;
                let flash_v = 2.0 * flash * (-r2 / 60.0).exp();
                // Smoke plume drifting up-right.
                let sdy = y as f64 - (muzzle.0 - 0.4 * time);
                let sdx = x as f64 - (muzzle.1 + 0.8 * time);
                let smoke_v = smoke_a * (-(sdy * sdy + sdx * sdx) / (2.0 * smoke_r * smoke_r)).exp();
                for ch in 0..c {
                    let idx = ((y * w + x) * c + ch) * f + fr;
                    data[idx] = bg[pix]
                        + bullet * flash_tint[ch]
                        + flash_v * flash_tint[ch]
                        + smoke_v * smoke_tint[ch];
                }
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> VideoConfig {
        VideoConfig { height: 20, width: 40, channels: 3, frames: 12, seed: 1 }
    }

    #[test]
    fn dims_and_nonneg() {
        let t = generate_video(&small());
        assert_eq!(t.dims(), &[20, 40, 3, 12]);
        assert!(t.is_nonneg());
    }

    #[test]
    fn temporal_structure_compresses() {
        let t = generate_video(&small());
        let tt = crate::baselines::ttsvd::tt_svd(&t, 0.05).unwrap();
        assert!(tt.compression_ratio() > 2.0, "got {}", tt.compression_ratio());
    }

    #[test]
    fn flash_decays_over_frames() {
        let t = generate_video(&small());
        // Mean intensity near the muzzle should decrease from frame 0 to late frames.
        let mean_at = |fr: usize| {
            let mut s = 0.0;
            for y in 8..12 {
                for x in 2..8 {
                    s += t.get(&[y, x, 0, fr]);
                }
            }
            s
        };
        assert!(mean_at(0) > mean_at(11));
    }

    #[test]
    fn deterministic() {
        assert_eq!(generate_video(&small()).as_slice(), generate_video(&small()).as_slice());
    }
}
