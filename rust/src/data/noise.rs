//! Noise injection for the Fig-9 denoising experiment.
//!
//! The paper adds Gaussian noise `N(0, 900)` (σ = 30 on 8-bit-scale
//! images) to every voxel of the Yale tensor. Values are clamped at zero
//! to preserve the non-negative domain the nTT requires (negative pixel
//! intensities are unphysical).

use crate::tensor::DenseTensor;
use crate::util::rng::Rng;

/// Add `N(0, sigma²)` noise to every element, clamping at 0.
pub fn add_gaussian_noise(t: &DenseTensor<f64>, sigma: f64, seed: u64) -> DenseTensor<f64> {
    let mut rng = Rng::new(seed);
    let mut out = t.clone();
    for x in out.as_mut_slice() {
        *x = (*x + rng.normal_ms(0.0, sigma)).max(0.0);
    }
    out
}

/// Peak-signal-to-noise ratio between a reference and a distorted tensor,
/// using the reference's max as peak.
pub fn psnr(reference: &DenseTensor<f64>, distorted: &DenseTensor<f64>) -> f64 {
    assert_eq!(reference.dims(), distorted.dims());
    let peak = reference.as_slice().iter().cloned().fold(0.0f64, f64::max);
    let mse: f64 = reference
        .as_slice()
        .iter()
        .zip(distorted.as_slice())
        .map(|(&a, &b)| (a - b) * (a - b))
        .sum::<f64>()
        / reference.len() as f64;
    if mse <= 0.0 {
        f64::INFINITY
    } else {
        10.0 * (peak * peak / mse).log10()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noise_changes_values_stays_nonneg() {
        let t = DenseTensor::<f64>::from_vec(&[4, 4], vec![0.5; 16]).unwrap();
        let n = add_gaussian_noise(&t, 0.3, 1);
        assert!(n.is_nonneg());
        assert!(t.rel_error(&n) > 0.05);
    }

    #[test]
    fn zero_sigma_identity() {
        let t = DenseTensor::<f64>::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let n = add_gaussian_noise(&t, 0.0, 2);
        assert_eq!(t.as_slice(), n.as_slice());
    }

    #[test]
    fn psnr_decreases_with_noise() {
        let t = DenseTensor::<f64>::from_vec(&[8, 8], vec![0.7; 64]).unwrap();
        let little = add_gaussian_noise(&t, 0.01, 3);
        let lots = add_gaussian_noise(&t, 0.3, 3);
        assert!(psnr(&t, &little) > psnr(&t, &lots));
        assert_eq!(psnr(&t, &t.clone()), f64::INFINITY);
    }
}
