//! Dataset substrates: synthetic Yale-B-like faces, synthetic high-speed
//! video, noise injection and image-quality metrics (SSIM/PSNR) for the
//! real-world experiments of §IV-C.

pub mod faces;
pub mod noise;
pub mod ssim;
pub mod video;

pub use faces::{generate_faces, FaceConfig};
pub use noise::{add_gaussian_noise, psnr};
pub use ssim::{mean_ssim_images, ssim};
pub use video::{generate_video, VideoConfig};
