//! Fig 9: denoising SSIM — SVD-TT vs NMF-TT on the noisy face tensor
//! across decreasing TT ranks / increasing compression.

use dntt::bench::workloads::{denoise_run, print_denoise, save_rows};
use dntt::data::FaceConfig;

fn main() {
    let fast = std::env::var("DNTT_BENCH_FAST").as_deref() == Ok("1");
    let faces = if fast {
        FaceConfig { height: 16, width: 14, illuminations: 8, subjects: 4, seed: 3435 }
    } else {
        FaceConfig { height: 24, width: 21, illuminations: 16, subjects: 10, seed: 3435 }
    };
    let ranks: &[usize] = if fast { &[8, 4, 2] } else { &[16, 12, 8, 6, 4, 2] };
    let rows = denoise_run(&faces, 0.12, ranks, if fast { 40 } else { 150 }).expect("fig9");
    print_denoise(&rows);
    save_rows("fig9_denoise", rows.iter().map(|r| r.to_json()).collect()).unwrap();
}
