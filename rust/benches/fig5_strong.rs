//! Fig 5: strong scaling — fixed tensor, grids 2^k x2x2x2 (k=1..5 in the
//! paper, BCD and MU, 100 NMF iterations), with the GR/MM/MAD/Norm/INIT +
//! AG/AR/RSC + IO breakdown and the alpha-beta cluster projection.

use dntt::bench::workloads::{print_scaling, save_rows, scaling_run, ScalingMode, ScalingParams};
use dntt::nmf::NmfAlgo;

fn main() {
    let fast = std::env::var("DNTT_BENCH_FAST").as_deref() == Ok("1");
    let params = ScalingParams {
        shrink: if fast { 16 } else { 8 },  // 16^4 / 32^4 tensor
        ks: if fast { vec![1, 2] } else { vec![1, 2, 3, 4, 5] },
        iters: if fast { 3 } else { 20 },
        algos: vec![NmfAlgo::Bcd, NmfAlgo::Mu],
        ..Default::default()
    };
    let pts = scaling_run(ScalingMode::Strong, &params).expect("fig5");
    print_scaling(&pts);
    save_rows("fig5_strong", pts.iter().map(|p| p.to_json()).collect()).unwrap();
}
