//! Fig 7: TT-rank scaling — p fixed, r in {2,4,8,16}.

use dntt::bench::workloads::{print_scaling, save_rows, scaling_run, ScalingMode, ScalingParams};
use dntt::nmf::NmfAlgo;

fn main() {
    let fast = std::env::var("DNTT_BENCH_FAST").as_deref() == Ok("1");
    let params = ScalingParams {
        shrink: if fast { 16 } else { 8 },
        ranks_p_exp: if fast { 2 } else { 5 }, // paper: 2^5*8 = 256 ranks
        rank_sweep: vec![2, 4, 8, 16],
        iters: if fast { 3 } else { 20 },
        algos: vec![NmfAlgo::Bcd, NmfAlgo::Mu],
        ..Default::default()
    };
    let pts = scaling_run(ScalingMode::Ranks, &params).expect("fig7");
    print_scaling(&pts);
    save_rows("fig7_ranks", pts.iter().map(|p| p.to_json()).collect()).unwrap();
}
