//! Micro: one distributed-NMF iteration's local kernels — allocating vs
//! workspace-reuse native path, plus the PJRT backend and the fused serial
//! PJRT iteration (the ablation for the L2 fusion claim). Emits
//! `bench_results/BENCH_micro_nmf.json`; `-- --smoke` trims the budget.

use dntt::bench::harness::Bench;
use dntt::linalg::gemm::matmul;
use dntt::linalg::Mat;
use dntt::nmf::NmfWorkspace;
use dntt::runtime::backend::ComputeBackend;
use dntt::runtime::native::NativeBackend;
use dntt::runtime::pjrt::{pjrt_nmf_iter, PjrtBackend};
use dntt::util::rng::Rng;
use std::path::Path;

fn main() {
    let mut b = Bench::from_env();
    let mut rng = Rng::new(2);
    // The quickstart stage-0 serial shape: X 16x4096, r 4.
    let (m, n, r) = (16usize, 4096usize, 4usize);
    let x = {
        let a = Mat::<f64>::rand_uniform(m, r, &mut rng);
        let c = Mat::<f64>::rand_uniform(r, n, &mut rng);
        matmul(&a, &c)
    };
    let w = Mat::<f64>::rand_uniform(m, r, &mut rng);
    let ht = Mat::<f64>::rand_uniform(n, r, &mut rng);
    // gram(ht) + x·ht + bcd's fm·g and elementwise tail.
    let step_flops = (n * r * r + 2 * m * n * r + 2 * m * r * r) as f64;

    let native = NativeBackend;
    b.run_case("native: gram+xht+bcd step (alloc)", &[m, n, r], step_flops, || {
        let hht = native.gram(&ht);
        let xht = native.xht(&x, &ht);
        native.bcd_update(&w, &hht, &xht, hht.fro_norm())
    });

    // Same step through a warm NmfWorkspace: zero allocation per
    // iteration (the form dist_nmf_ws runs).
    let mut ws = NmfWorkspace::new();
    let mut hht = Mat::<f64>::zeros(r, r);
    let mut xht = Mat::<f64>::zeros(m, r);
    let mut wout = Mat::<f64>::zeros(m, r);
    b.run_case("native: gram+xht+bcd step (workspace)", &[m, n, r], step_flops, || {
        native.gram_into(&ht, &mut hht, &mut ws.kernel);
        native.xht_into(&x, &ht, &mut xht, &mut ws.kernel);
        let lip = hht.fro_norm();
        native.bcd_update_into(&w, &hht, &xht, lip, &mut wout, &mut ws.kernel);
    });

    if Path::new("artifacts/manifest.json").exists() {
        let pjrt = PjrtBackend::from_dir(Path::new("artifacts")).expect("pjrt");
        // Warm the executable cache outside the timer.
        let _ = pjrt.gram(&ht);
        b.run("pjrt: gram+xht+bcd step (op-per-call)", || {
            let hht = pjrt.gram(&ht);
            let xht = pjrt.xht(&x, &ht);
            pjrt.bcd_update(&w, &hht, &xht, hht.fro_norm())
        });
        if pjrt_nmf_iter(&pjrt, &x, &w, &ht).is_some() {
            b.run("pjrt: fused full BCD iteration", || {
                pjrt_nmf_iter(&pjrt, &x, &w, &ht).unwrap()
            });
        }
        let hits = pjrt.engine().stats.hits.load(std::sync::atomic::Ordering::Relaxed);
        println!("    (pjrt hits: {hits})");
    } else {
        println!("(artifacts missing; pjrt comparison skipped)");
    }
    b.save("micro_nmf").unwrap();
}
