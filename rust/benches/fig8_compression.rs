//! Fig 8a/8b/8c: compression vs error on faces, video and the large
//! synthetic tensor (BCD vs MU on 8c).

use dntt::bench::workloads::{fig8_sweep, print_sweep, save_rows, Fig8Data, PAPER_EPS};

fn main() {
    let fast = std::env::var("DNTT_BENCH_FAST").as_deref() == Ok("1");
    let (iters, eps): (usize, &[f64]) =
        if fast { (20, &[0.5, 0.075, 0.005]) } else { (80, &PAPER_EPS) };
    // Per-figure scales: 8a/8b at the paper's true sizes in full mode; the
    // 8c tensor is the paper's 500 GB workload divided by 16 per mode
    // (2.1M elements — compression ratios are size-independent at fixed
    // ranks; examples/large_compression.rs runs the bigger instances).
    for (tag, which, scale) in [
        ("fig8a_faces", Fig8Data::Faces, if fast { 8 } else { 1 }),
        ("fig8b_video", Fig8Data::Video, if fast { 8 } else { 1 }),
        ("fig8c_large", Fig8Data::LargeSynthetic, if fast { 32 } else { 16 }),
    ] {
        println!("=== {tag} ===");
        let rows = fig8_sweep(which, eps, iters, scale).expect(tag);
        print_sweep(&rows);
        save_rows(tag, rows.iter().map(|r| r.to_json()).collect()).unwrap();
        println!();
    }
}
