//! Fig 2: compression vs relative error — TT, nTT, Tucker, nTucker on a
//! synthetic n^4 tensor (paper: 32^4). Prints the four curves and saves
//! them to bench_results/BENCH_fig2.json.

use dntt::bench::workloads::{fig2_sweep, print_sweep, save_rows, PAPER_EPS};

fn main() {
    let fast = std::env::var("DNTT_BENCH_FAST").as_deref() == Ok("1");
    let (n, iters, eps): (usize, usize, &[f64]) = if fast {
        (8, 25, &[0.5, 0.075, 0.001])
    } else {
        (16, 100, &PAPER_EPS)
    };
    println!("fig2: {n}^4 synthetic, {iters} NMF iters");
    let rows = fig2_sweep(n, eps, iters).expect("fig2 sweep");
    print_sweep(&rows);
    save_rows("fig2", rows.iter().map(|r| r.to_json()).collect()).unwrap();
}
