//! Micro: serve-layer query throughput (the read side of the system).
//!
//! Measures [`dntt::serve::TtHandle`] batched point queries against the
//! naive per-element chain on the same random query stream over a 16^4
//! TT with internal ranks [8, 8, 8] — the acceptance case is batch size
//! 4096, where prefix caching over the sorted batch must buy ≥ 2× over
//! `TTensor::element` per query (warn-only CI gate in
//! `bench/baseline.json`). Both sides of each pair are credited with the
//! same nominal flops (2·Σ r·r′ per point), so the GF/s ratio in the
//! `dntt-bench-v1` envelope *is* the throughput ratio. Emits
//! `bench_results/BENCH_query_throughput.json`; `-- --smoke` trims the
//! timing budget but keeps every batch size.

use dntt::bench::harness::Bench;
use dntt::serve::{QueryWorkspace, TtHandle};
use dntt::tensor::TTensor;
use dntt::util::rng::Rng;

fn main() {
    let mut b = Bench::from_env();
    let mut rng = Rng::new(42);

    let dims = [16usize, 16, 16, 16];
    let inner = [8usize, 8, 8];
    let tt = TTensor::<f64>::rand_uniform(&dims, &inner, &mut rng).expect("tt fixture");
    // Nominal per-point cost of the uncached chain: one fma per
    // (left-rank, right-rank) pair of every core row.
    let ranks = tt.ranks().to_vec();
    let point_flops: f64 = ranks.windows(2).map(|w| 2.0 * (w[0] * w[1]) as f64).sum();
    let handle = TtHandle::new(tt);
    let mut ws = QueryWorkspace::new();

    let d = dims.len();
    for &q in &[1usize, 64, 4096] {
        let queries: Vec<usize> = (0..q * d).map(|i| rng.below(dims[i % d])).collect();
        let flops = q as f64 * point_flops;
        let mut out = Vec::with_capacity(q);
        b.run_case(&format!("tt_batched q={q}"), &[q, d], flops, || {
            handle.batch_into(&queries, &mut ws, &mut out).expect("batched query")
        });
        let tt = handle.tt();
        b.run_case(&format!("tt_naive q={q}"), &[q, d], flops, || {
            let mut acc = 0.0f64;
            for idx in queries.chunks(d) {
                acc += tt.element(idx);
            }
            std::hint::black_box(acc);
        });
    }

    // Console summary of the acceptance ratio (batched ≥ 2× at q=4096).
    let gf = |name: &str| {
        b.results().iter().find(|s| s.name == name).map(|s| s.gflops()).unwrap_or(0.0)
    };
    let naive = gf("tt_naive q=4096");
    let batched = gf("tt_batched q=4096");
    if naive > 0.0 {
        println!(
            "\n16^4 r8 q=4096: naive {naive:.3} GF/s, batched {batched:.3} GF/s ({:.2}x)",
            batched / naive
        );
    }
    b.save("query_throughput").unwrap();
}
