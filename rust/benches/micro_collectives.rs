//! Micro: thread-rank collectives at NMF-realistic message sizes.

use dntt::bench::harness::Bench;
use dntt::dist::Comm;

fn bench_collective(b: &mut Bench, name: &str, p: usize, len: usize, which: u8) {
    b.run(&format!("{name} p={p} len={len}"), || {
        Comm::run(p, move |mut c| match which {
            0 => {
                let mut v = vec![1.0f64; len];
                c.all_reduce_sum(&mut v);
                v[0]
            }
            1 => c.all_gather(&vec![1.0f64; len])[0],
            _ => c.reduce_scatter_sum(&vec![1.0f64; len * c.size()]).unwrap()[0],
        })
    });
}

fn main() {
    let mut b = Bench::from_env();
    for &p in &[4usize, 16] {
        bench_collective(&mut b, "all_reduce", p, 100, 0); // r x r gram
        bench_collective(&mut b, "all_reduce", p, 10_000, 0);
        bench_collective(&mut b, "all_gather", p, 10_000, 1); // factor panel
        bench_collective(&mut b, "reduce_scatter", p, 10_000, 2);
    }
    b.save("micro_collectives").unwrap();
}
