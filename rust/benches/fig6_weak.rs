//! Fig 6: weak scaling — per-rank data fixed; tensor first dim and grid
//! first dim both scale as 2^(k-1).

use dntt::bench::workloads::{print_scaling, save_rows, scaling_run, ScalingMode, ScalingParams};
use dntt::nmf::NmfAlgo;

fn main() {
    let fast = std::env::var("DNTT_BENCH_FAST").as_deref() == Ok("1");
    let params = ScalingParams {
        shrink: if fast { 16 } else { 8 },
        ks: if fast { vec![1, 2] } else { vec![1, 2, 3, 4, 5] },
        iters: if fast { 3 } else { 20 },
        algos: vec![NmfAlgo::Bcd, NmfAlgo::Mu],
        ..Default::default()
    };
    let pts = scaling_run(ScalingMode::Weak, &params).expect("fig6");
    print_scaling(&pts);
    save_rows("fig6_weak", pts.iter().map(|p| p.to_json()).collect()).unwrap();
}
