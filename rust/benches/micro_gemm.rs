//! Micro: GEMM kernel suite (the MM/GR hot path).
//!
//! Measures the packed register-blocked microkernel against the seed
//! blocked kernel on identical shapes — the headline case is the
//! 512×512×512 f64 multiply the CI perf gate tracks (acceptance: packed
//! ≥ 2× blocked GF/s). Emits `bench_results/BENCH_micro_gemm.json`
//! (`dntt-bench-v1` envelope: shape, flops, ns/iter, GF/s, git sha);
//! `-- --smoke` trims the timing budget but keeps every shape so the CI
//! artifact always carries the full comparison.

use dntt::bench::harness::Bench;
use dntt::linalg::gemm::{
    gram_mt_m, matmul_a_bt_into_ws, matmul_at_b_into_ws, matmul_blocked_into, matmul_into_ws,
    matmul_packed_into, matmul_packed_with, GemmWorkspace,
};
use dntt::linalg::simd::default_path;
use dntt::linalg::{KernelCfg, Mat};
use dntt::util::rng::Rng;

fn main() {
    let mut b = Bench::from_env();
    let mut rng = Rng::new(1);
    let mut ws = GemmWorkspace::<f64>::new();
    // The packed cases dispatch through the env-aware default selection;
    // tag them with the resolved path so the auto-vs-scalar ratio gate in
    // bench/baseline.json can verify it compares the paths it claims to.
    let auto = default_path().name();

    // --- Square + NMF-shaped A·B: blocked (seed) vs packed. -------------
    // 512^3 is the CI perf-gate headline; the rest cover the stage-matrix
    // aspect ratios (tall·skinny and short·deep) of Algs 5–6.
    for &(m, k, n) in &[
        (512usize, 512usize, 512usize),
        (256, 256, 256),
        (1024, 64, 16),
        (64, 4096, 16),
    ] {
        let a = Mat::<f64>::rand_uniform(m, k, &mut rng);
        let bm = Mat::<f64>::rand_uniform(k, n, &mut rng);
        let mut c = Mat::<f64>::zeros(m, n);
        let flops = 2.0 * (m * k * n) as f64;
        b.run_kernel_case(&format!("matmul_blocked {m}x{k}x{n} f64"), &[m, k, n], flops, "scalar", || {
            matmul_blocked_into(&a, &bm, &mut c)
        });
        b.run_kernel_case(&format!("matmul_packed {m}x{k}x{n} f64"), &[m, k, n], flops, auto, || {
            matmul_packed_into(&a, &bm, &mut c, &mut ws)
        });
        if (m, k, n) == (512, 512, 512) {
            // Headline comparisons for the SIMD speedup gate: the same
            // packed loop forced to the scalar microkernel, and the auto
            // path with 4 intra-rank threads (all bitwise identical).
            b.run_kernel_case(
                &format!("matmul_packed_scalar {m}x{k}x{n} f64"),
                &[m, k, n],
                flops,
                "scalar",
                || matmul_packed_with(&a, &bm, &mut c, &mut ws, KernelCfg::scalar()),
            );
            let t4 = KernelCfg::new(default_path(), 4);
            b.run_kernel_case(
                &format!("matmul_packed_t4 {m}x{k}x{n} f64"),
                &[m, k, n],
                flops,
                auto,
                || matmul_packed_with(&a, &bm, &mut c, &mut ws, t4),
            );
        }
    }

    // f32 headline (the PJRT artifact dtype).
    {
        let (m, k, n) = (512usize, 512usize, 512usize);
        let a = Mat::<f32>::rand_uniform(m, k, &mut rng);
        let bm = Mat::<f32>::rand_uniform(k, n, &mut rng);
        let mut c = Mat::<f32>::zeros(m, n);
        let mut ws32 = GemmWorkspace::<f32>::new();
        let flops = 2.0 * (m * k * n) as f64;
        b.run_kernel_case(&format!("matmul_packed {m}x{k}x{n} f32"), &[m, k, n], flops, auto, || {
            matmul_packed_into(&a, &bm, &mut c, &mut ws32)
        });
    }

    // --- Gram kernels (GR of Alg 4). -------------------------------------
    for &(rows, r) in &[(4096usize, 10usize), (65536, 10), (4096, 40)] {
        let f = Mat::<f64>::rand_uniform(rows, r, &mut rng);
        b.run_case(&format!("gram {rows}x{r}"), &[rows, r], (rows * r * r) as f64, || {
            gram_mt_m(&f)
        });
    }

    // --- The NMF product kernels at quickstart scale (workspace path). ---
    let x = Mat::<f64>::rand_uniform(1024, 2048, &mut rng);
    let ht = Mat::<f64>::rand_uniform(2048, 10, &mut rng);
    let mut out = Mat::<f64>::zeros(1024, 10);
    b.run_kernel_case("xht 1024x2048x10 (A*B)", &[1024, 2048, 10], 2.0 * (1024 * 2048 * 10) as f64, auto, || {
        matmul_into_ws(&x, &ht, &mut out, &mut ws)
    });
    let w = Mat::<f64>::rand_uniform(1024, 10, &mut rng);
    let mut out2 = Mat::<f64>::zeros(2048, 10);
    b.run_kernel_case("wtx 1024x2048x10 (At*B)", &[2048, 1024, 10], 2.0 * (1024 * 2048 * 10) as f64, auto, || {
        matmul_at_b_into_ws(&x, &w, &mut out2, &mut ws)
    });
    let h2 = Mat::<f64>::rand_uniform(10, 2048, &mut rng);
    let mut out3 = Mat::<f64>::zeros(1024, 10);
    b.run_kernel_case("a_bt 1024x2048x10 (A*Bt)", &[1024, 2048, 10], 2.0 * (1024 * 2048 * 10) as f64, auto, || {
        matmul_a_bt_into_ws(&x, &h2, &mut out3, &mut ws)
    });

    // Console summary of the acceptance ratios.
    let gf = |name: &str| {
        b.results().iter().find(|s| s.name == name).map(|s| s.gflops()).unwrap_or(0.0)
    };
    let blocked = gf("matmul_blocked 512x512x512 f64");
    let packed = gf("matmul_packed 512x512x512 f64");
    let scalar = gf("matmul_packed_scalar 512x512x512 f64");
    if blocked > 0.0 {
        println!(
            "\n512^3 f64: blocked {blocked:.2} GF/s, packed {packed:.2} GF/s ({:.2}x)",
            packed / blocked
        );
    }
    if scalar > 0.0 {
        println!(
            "512^3 f64: scalar {scalar:.2} GF/s, {auto} {packed:.2} GF/s ({:.2}x SIMD speedup)",
            packed / scalar
        );
    }
    b.save("micro_gemm").unwrap();
}
