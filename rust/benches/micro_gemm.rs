//! Micro: GEMM kernel suite (the MM/GR hot path). Reports GFLOP/s per
//! shape so the §Perf roofline discussion in EXPERIMENTS.md is grounded.

use dntt::bench::harness::Bench;
use dntt::linalg::gemm::{gram_mt_m, matmul, matmul_a_bt, matmul_at_b};
use dntt::linalg::Mat;
use dntt::util::rng::Rng;

fn main() {
    let mut b = Bench::from_env();
    let mut rng = Rng::new(1);
    for &(m, k, n) in &[(256usize, 256usize, 256usize), (1024, 64, 16), (64, 4096, 16)] {
        let a = Mat::<f64>::rand_uniform(m, k, &mut rng);
        let bm = Mat::<f64>::rand_uniform(k, n, &mut rng);
        let stats = b.run(&format!("matmul {m}x{k}x{n}"), || matmul(&a, &bm)).clone();
        let gflops = 2.0 * (m * k * n) as f64 / stats.median_s / 1e9;
        println!("    -> {gflops:.2} GFLOP/s");
    }
    for &(rows, r) in &[(4096usize, 10usize), (65536, 10), (4096, 40)] {
        let f = Mat::<f64>::rand_uniform(rows, r, &mut rng);
        let stats = b.run(&format!("gram {rows}x{r}"), || gram_mt_m(&f)).clone();
        let gflops = (rows * r * r) as f64 / stats.median_s / 1e9;
        println!("    -> {gflops:.2} GFLOP/s");
    }
    let x = Mat::<f64>::rand_uniform(1024, 2048, &mut rng);
    let ht = Mat::<f64>::rand_uniform(2048, 10, &mut rng);
    b.run("xht 1024x2048x10 (A*B)", || matmul(&x, &ht));
    let w = Mat::<f64>::rand_uniform(1024, 10, &mut rng);
    b.run("wtx 1024x2048x10 (At*B)", || matmul_at_b(&x, &w));
    let h2 = Mat::<f64>::rand_uniform(10, 2048, &mut rng);
    b.run("a_bt 1024x2048x10 (A*Bt)", || matmul_a_bt(&x, &h2));
    b.save("micro_gemm").unwrap();
}
