//! Micro: sparse (CSR SpMM) vs packed dense GEMM on the NMF product
//! shapes, across a density sweep.
//!
//! Both kernels are credited with the *nominal dense* flop count
//! (`2·m·k·n`), so the reported GF/s are effective rates and the
//! sparse/dense GF/s ratio is exactly the wall-clock speedup. The CI
//! perf gate (`rust/bench/baseline.json`) asserts warn-only that the
//! sparse kernel beats the packed dense kernel at 99% sparsity
//! (`d=0.01`). Emits `bench_results/BENCH_sparse_vs_dense.json`
//! (`dntt-bench-v1` envelope); `-- --smoke` trims the timing budget but
//! keeps every density so the CI artifact always carries the full sweep
//! for EXPERIMENTS.md §Sparse.

use dntt::bench::harness::Bench;
use dntt::linalg::gemm::{matmul_at_b_into_ws, matmul_into_ws, GemmWorkspace};
use dntt::linalg::simd::default_path;
use dntt::linalg::sparse::{
    sp_matmul_at_b_into, sp_matmul_at_b_with, sp_matmul_into, sp_matmul_with, SparseMat,
};
use dntt::linalg::{KernelCfg, Mat};
use dntt::util::rng::Rng;

/// Dense non-negative matrix with exact zeros at the given density.
fn sparse_x(m: usize, n: usize, density: f64, rng: &mut Rng) -> Mat<f64> {
    Mat::from_fn(m, n, |_, _| {
        if rng.uniform() < density {
            0.5 + rng.uniform()
        } else {
            0.0
        }
    })
}

fn main() {
    let mut b = Bench::from_env();
    let mut rng = Rng::new(1);
    let mut ws = GemmWorkspace::<f64>::new();
    // Kernel-path tag for the dispatched cases (env-aware default).
    let auto = default_path().name();
    let sel = KernelCfg::default();

    // The quickstart-scale NMF product shapes (X: 1024×2048, r = 10).
    let (m, k, r) = (1024usize, 2048usize, 10usize);
    let flops = 2.0 * (m * k * r) as f64;
    let ht = Mat::<f64>::rand_uniform(k, r, &mut rng);
    let w = Mat::<f64>::rand_uniform(m, r, &mut rng);

    // Dense packed baselines (density-independent).
    let xd = sparse_x(m, k, 1.0, &mut rng);
    let mut out = Mat::<f64>::zeros(m, r);
    b.run_kernel_case(&format!("xht_dense {m}x{k}x{r}"), &[m, k, r], flops, auto, || {
        matmul_into_ws(&xd, &ht, &mut out, &mut ws)
    });
    let mut out_t = Mat::<f64>::zeros(k, r);
    b.run_kernel_case(&format!("wtx_dense {m}x{k}x{r}"), &[k, m, r], flops, auto, || {
        matmul_at_b_into_ws(&xd, &w, &mut out_t, &mut ws)
    });

    // Density sweep: the EXPERIMENTS.md §Sparse schedule. The `_into`
    // forms are the scalar reference kernels; the `_simd` cases run the
    // dispatched `_with` forms (bitwise identical, different speed).
    for &density in &[0.01f64, 0.1, 0.5, 1.0] {
        let x = sparse_x(m, k, density, &mut rng);
        let xs = SparseMat::from_dense(&x);
        b.run_kernel_case(
            &format!("xht_sparse {m}x{k}x{r} d={density}"),
            &[m, k, r],
            flops,
            "scalar",
            || sp_matmul_into(&xs, &ht, &mut out),
        );
        b.run_kernel_case(
            &format!("xht_sparse_simd {m}x{k}x{r} d={density}"),
            &[m, k, r],
            flops,
            auto,
            || sp_matmul_with(&xs, &ht, &mut out, sel),
        );
        b.run_kernel_case(
            &format!("wtx_sparse {m}x{k}x{r} d={density}"),
            &[k, m, r],
            flops,
            "scalar",
            || sp_matmul_at_b_into(&xs, &w, &mut out_t),
        );
        b.run_kernel_case(
            &format!("wtx_sparse_simd {m}x{k}x{r} d={density}"),
            &[k, m, r],
            flops,
            auto,
            || sp_matmul_at_b_with(&xs, &w, &mut out_t, sel),
        );
    }

    // Console summary of the acceptance ratio (99% sparsity headline).
    let gf = |name: &str| {
        b.results().iter().find(|s| s.name == name).map(|s| s.gflops()).unwrap_or(0.0)
    };
    let dense = gf(&format!("xht_dense {m}x{k}x{r}"));
    let sparse = gf(&format!("xht_sparse {m}x{k}x{r} d=0.01"));
    if dense > 0.0 {
        println!(
            "\nxht at d=0.01: dense {dense:.2} GF/s, sparse {sparse:.2} effective GF/s ({:.2}x)",
            sparse / dense
        );
    }
    b.save("sparse_vs_dense").unwrap();
}
