"""L2 correctness: the fused serial NMF iteration vs step-by-step refs,
including objective monotonicity when driven exactly like the Rust loop."""

import numpy as np
import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def stepwise_iter(x, wm, htm):
    hht = ref.gram_ref(htm)
    xht = ref.xht_ref(x, htm)
    lip_w = jnp.sqrt(jnp.sum(hht * hht)).reshape(1, 1)
    w = ref.bcd_update_ref(wm, hht, xht, lip_w)
    wtw = ref.gram_ref(w)
    xtw = ref.wtx_ref(x, w)
    lip_h = jnp.sqrt(jnp.sum(wtw * wtw)).reshape(1, 1)
    ht = ref.bcd_update_ref(htm, wtw, xtw, lip_h)
    hht2 = ref.gram_ref(ht)
    cross = jnp.sum(xtw * ht)
    quad = jnp.sum(wtw * hht2)
    return w, ht, cross, quad


def test_fused_iter_matches_stepwise():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.random((10, 14), dtype=np.float32))
    wm = jnp.asarray(rng.random((10, 3), dtype=np.float32))
    htm = jnp.asarray(rng.random((14, 3), dtype=np.float32))
    w1, ht1, c1, q1 = model.nmf_iter_bcd(x, wm, htm)
    w2, ht2, c2, q2 = stepwise_iter(x, wm, htm)
    np.testing.assert_allclose(w1, w2, rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(ht1, ht2, rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(c1[0], c2, rtol=2e-4)
    np.testing.assert_allclose(q1[0], q2, rtol=2e-4)


def test_iterating_reduces_objective():
    rng = np.random.default_rng(2)
    a = rng.random((12, 3)).astype(np.float32)
    b = rng.random((3, 16)).astype(np.float32)
    x = jnp.asarray(a @ b)
    xsq = float(jnp.sum(x * x))
    w = jnp.asarray(rng.random((12, 3), dtype=np.float32))
    ht = jnp.asarray(rng.random((16, 3), dtype=np.float32))
    objs = []
    for _ in range(80):
        w, ht, cross, quad = model.nmf_iter_bcd(x, w, ht)
        objs.append(0.5 * (xsq - 2.0 * float(cross[0]) + float(quad[0])))
    # Plain (non-extrapolated) BCD is monotone.
    for a0, a1 in zip(objs, objs[1:]):
        assert a1 <= a0 * (1.0 + 1e-5)
    assert objs[-1] < 0.2 * objs[0]


def test_ops_exposed():
    rng = np.random.default_rng(3)
    f = jnp.asarray(rng.random((6, 2), dtype=np.float32))
    np.testing.assert_allclose(model.gram(f), ref.gram_ref(f), rtol=2e-4)
