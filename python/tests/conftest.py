"""Enable 64-bit mode so the f64 dtype sweeps really run in f64.

The AOT artifacts are f32 (aot.py pins dtypes explicitly); enabling x64
here only affects the in-process correctness tests.
"""

import jax

jax.config.update("jax_enable_x64", True)
