"""AOT path: lowering produces parseable HLO text and a consistent manifest."""

import json
import os
import subprocess
import sys
import tempfile

from compile import aot


def test_stage_shapes_mirror_alg2():
    # dims 16^4, ranks 4 on a 1x1 grid: stage matrices are
    # 16x4096 (r=4), 64x256 (r=4), 64x16 (r=4).
    shapes = aot.stage_shapes([16] * 4, [4, 4, 4], 1, 1)
    xht = sorted(d for op, d in shapes if op == "xht")
    assert (16, 4096, 4) in xht
    assert (64, 256, 4) in xht
    assert (64, 16, 4) in xht
    # Serial grid also emits the fused iteration.
    assert ("nmf_iter_bcd", (16, 4096, 4)) in shapes


def test_stage_shapes_skip_nondividing():
    # 6^3 on a 4x4 grid: 6 % 4 != 0 everywhere → nothing emitted.
    shapes = aot.stage_shapes([6] * 3, [2, 2], 4, 4)
    assert shapes == []


def test_lowering_emits_hlo_text():
    text = aot.to_hlo_text(
        lambda a, b: (a @ b,), aot.spec(4, 6), aot.spec(6, 2)
    )
    assert "HloModule" in text
    assert "f32[4,6]" in text


def test_full_aot_run_writes_manifest():
    with tempfile.TemporaryDirectory() as d:
        env = dict(os.environ)
        out = subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out", d],
            capture_output=True,
            text=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            env=env,
            timeout=900,
        )
        assert out.returncode == 0, out.stderr
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        assert manifest["dtype"] == "f32"
        assert len(manifest["ops"]) > 10
        for op in manifest["ops"]:
            path = os.path.join(d, op["file"])
            assert os.path.exists(path)
            with open(path) as fh:
                head = fh.read(200)
            assert "HloModule" in head
