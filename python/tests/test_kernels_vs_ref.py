"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes; assert_allclose against ref.py is the
core correctness signal for the compile path (see ref.py's module docs).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import nmf_update as k
from compile.kernels import ref

DTYPES = [np.float32, np.float64]


def arr(rng, shape, dtype):
    return jnp.asarray(rng.random(shape).astype(dtype))


dims = st.integers(min_value=1, max_value=40)
ranks = st.integers(min_value=1, max_value=9)
dtypes = st.sampled_from(DTYPES)


def tol(dtype):
    return dict(rtol=2e-4, atol=2e-5) if dtype == np.float32 else dict(rtol=1e-9, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(rows=dims, r=ranks, dtype=dtypes, seed=st.integers(0, 2**31))
def test_gram_matches_ref(rows, r, dtype, seed):
    rng = np.random.default_rng(seed)
    f = arr(rng, (rows, r), dtype)
    np.testing.assert_allclose(k.gram(f), ref.gram_ref(f), **tol(dtype))


@settings(max_examples=25, deadline=None)
@given(mi=dims, nj=dims, r=ranks, dtype=dtypes, seed=st.integers(0, 2**31))
def test_xht_matches_ref(mi, nj, r, dtype, seed):
    rng = np.random.default_rng(seed)
    x = arr(rng, (mi, nj), dtype)
    ht = arr(rng, (nj, r), dtype)
    np.testing.assert_allclose(k.xht(x, ht), ref.xht_ref(x, ht), **tol(dtype))


@settings(max_examples=25, deadline=None)
@given(mi=dims, nj=dims, r=ranks, dtype=dtypes, seed=st.integers(0, 2**31))
def test_wtx_matches_ref(mi, nj, r, dtype, seed):
    rng = np.random.default_rng(seed)
    x = arr(rng, (mi, nj), dtype)
    w = arr(rng, (mi, r), dtype)
    np.testing.assert_allclose(k.wtx(x, w), ref.wtx_ref(x, w), **tol(dtype))


@settings(max_examples=25, deadline=None)
@given(rows=dims, r=ranks, dtype=dtypes, seed=st.integers(0, 2**31))
def test_bcd_update_matches_ref(rows, r, dtype, seed):
    rng = np.random.default_rng(seed)
    fm = arr(rng, (rows, r), dtype)
    g = ref.gram_ref(arr(rng, (rows + 1, r), dtype))
    p = arr(rng, (rows, r), dtype)
    lip = jnp.asarray([[np.float64(np.linalg.norm(g)) + 1e-6]], dtype=dtype)
    got = k.bcd_update(fm, g, p, lip)
    want = ref.bcd_update_ref(fm, g, p, lip)
    np.testing.assert_allclose(got, want, **tol(dtype))
    assert np.all(np.asarray(got) >= 0.0)


@settings(max_examples=25, deadline=None)
@given(rows=dims, r=ranks, dtype=dtypes, seed=st.integers(0, 2**31))
def test_mu_update_matches_ref(rows, r, dtype, seed):
    rng = np.random.default_rng(seed)
    f = arr(rng, (rows, r), dtype)
    g = ref.gram_ref(arr(rng, (rows + 1, r), dtype))
    p = arr(rng, (rows, r), dtype)
    got = k.mu_update(f, g, p)
    np.testing.assert_allclose(got, ref.mu_update_ref(f, g, p), **tol(dtype))
    assert np.all(np.asarray(got) >= 0.0)


@pytest.mark.parametrize("rows,r", [(1, 1), (128, 4), (129, 7), (256, 1)])
def test_gram_tile_boundaries(rows, r):
    """Exact multiples, sub-tile and non-dividing sizes all tile correctly."""
    rng = np.random.default_rng(0)
    f = arr(rng, (rows, r), np.float32)
    np.testing.assert_allclose(k.gram(f), ref.gram_ref(f), rtol=2e-4, atol=1e-5)


def test_tile_helper_divides():
    for n in [1, 7, 64, 100, 128, 129, 1000]:
        t = k._tile(n, 128)
        assert 1 <= t <= min(n, 128)
        assert n % t == 0
