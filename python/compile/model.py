"""Layer-2 JAX compute graphs for the distributed NMF.

Two kinds of graphs are lowered:

* the five **local ops** (`gram`, `xht`, `wtx`, `bcd_update`, `mu_update`)
  — the per-rank compute between collectives, each calling its L1 Pallas
  kernel so the kernel lowers into the op's HLO;
* the **fused serial iteration** (`nmf_iter_bcd`) — on a single rank (no
  collectives) one whole BCD iteration is a single XLA program: both
  factor updates, both Gram refreshes, both product refreshes and the
  objective terms fuse into one executable, eliminating per-op dispatch
  from the Rust hot loop.

All graphs take/return f32 (the artifact dtype); the Rust native backend
is f64 and parity is asserted at 1e-3 relative tolerance.
"""

import jax.numpy as jnp

from .kernels import nmf_update as k


def gram(f):
    return k.gram(f)


def xht(x, ht):
    return k.xht(x, ht)


def wtx(x, w):
    return k.wtx(x, w)


def bcd_update(fm, g, p, lip):
    return k.bcd_update(fm, g, p, lip)


def mu_update(f, g, p):
    return k.mu_update(f, g, p)


def nmf_iter_bcd(x, wm, htm):
    """One full serial BCD iteration as a single fused graph.

    Inputs: X (m×n), momentum factors Wm (m×r), Htm (n×r).
    Returns (W', Ht', obj_terms) where obj_terms = (cross, quad):
      objective = 0.5 * (‖X‖² − 2·cross + quad)  computed by the caller
      (‖X‖² is constant and stays host-side).
    """
    hht = k.gram(htm)
    xht_ = k.xht(x, htm)
    lip_w = jnp.sqrt(jnp.sum(hht * hht)).reshape(1, 1)
    w_new = k.bcd_update(wm, hht, xht_, lip_w)

    wtw = k.gram(w_new)
    xtw = k.wtx(x, w_new)
    lip_h = jnp.sqrt(jnp.sum(wtw * wtw)).reshape(1, 1)
    ht_new = k.bcd_update(htm, wtw, xtw, lip_h)

    hht_new = k.gram(ht_new)
    cross = jnp.sum(xtw * ht_new).reshape(1)
    quad = jnp.sum(wtw * hht_new).reshape(1)
    return w_new, ht_new, cross, quad
