"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package is validated against these definitions by
``python/tests/test_kernels_vs_ref.py`` (hypothesis sweeps shapes/dtypes)
— this is the L1 correctness signal for the whole stack: the Rust native
backend mirrors these same formulas, and the PJRT backend runs the lowered
kernels, so agreement here + agreement in `tests/integration_runtime.rs`
closes the loop.
"""

import jax.numpy as jnp

# Epsilon guarding MU divisions; must match rust/src/runtime/backend.rs.
MU_EPS = 1e-16


def gram_ref(f):
    """Fᵀ·F for a (rows × r) factor block -> (r × r)."""
    return f.T @ f


def xht_ref(x, ht):
    """X·H̃ for X (mi × nj), Ht (nj × r) -> (mi × r). The local Alg-5 GEMM."""
    return x @ ht


def wtx_ref(x, w):
    """Xᵀ·W for X (mi × nj), W (mi × r) -> (nj × r). The local Alg-6 GEMM."""
    return x.T @ w


def bcd_update_ref(fm, g, p, lip):
    """Projected-gradient BCD step (Alg 3 lines 6-8 / 11-14).

    max(0, Fm − (Fm·G − P) / lip); `lip` is a (1,1) array so the same HLO
    signature serves any step size.
    """
    return jnp.maximum(0.0, fm - (fm @ g - p) / lip[0, 0])


def mu_update_ref(f, g, p):
    """Lee–Seung multiplicative step: F ⊙ P ⊘ (F·G + ε)."""
    return f * p / (f @ g + MU_EPS)
