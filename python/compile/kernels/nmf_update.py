"""Layer-1 Pallas kernels for the distributed-NMF hot spots.

Each local (per-rank) operation of Algs 3–6 is written as a Pallas kernel
with an explicit ``BlockSpec`` HBM→VMEM schedule:

* ``gram``        — Fᵀ·F, row-tile reduction into an (r × r) accumulator;
* ``xht``         — X·H̃, 2-D tiling with k-dimension accumulation (MXU-
                    shaped (128,128) tiles when the shape allows);
* ``wtx``         — Xᵀ·W, the transposed variant;
* ``bcd_update``  — the fused projected-gradient step: the (rows × r)
                    factor tile stays resident in VMEM across the GEMM,
                    subtraction, scaling and ReLU projection — one HBM
                    round-trip where a naive composition needs four;
* ``mu_update``   — the fused multiplicative step.

TPU adaptation note (DESIGN.md §Hardware-Adaptation): the paper targets a
CPU cluster where BLAS does the blocking implicitly; here the same blocking
is explicit so the kernels are MXU/VMEM-shaped. On this CPU-only image they
MUST run ``interpret=True`` — real TPU lowering emits Mosaic custom-calls
the CPU PJRT client cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import MU_EPS

# Preferred tile sizes (MXU-aligned on TPU).
TILE_ROWS = 128
TILE_K = 128


def _tile(n: int, pref: int) -> int:
    """Largest divisor of n that is ≤ pref (so BlockSpecs tile exactly)."""
    t = min(n, pref)
    while n % t != 0:
        t -= 1
    return max(t, 1)


# --------------------------------------------------------------------------
# gram: Fᵀ·F
# --------------------------------------------------------------------------

def _gram_kernel(f_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    f = f_ref[...]
    o_ref[...] += jnp.dot(f.T, f, preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=())
def gram(f):
    rows, r = f.shape
    bm = _tile(rows, TILE_ROWS)
    return pl.pallas_call(
        _gram_kernel,
        grid=(rows // bm,),
        in_specs=[pl.BlockSpec((bm, r), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((r, r), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((r, r), f.dtype),
        interpret=True,
    )(f)


# --------------------------------------------------------------------------
# xht: X·H̃  (mi × nj)·(nj × r) with k-accumulation
# --------------------------------------------------------------------------

def _matmul_acc_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype)


def xht(x, ht):
    mi, nj = x.shape
    _, r = ht.shape
    bm = _tile(mi, TILE_ROWS)
    bk = _tile(nj, TILE_K)
    return pl.pallas_call(
        _matmul_acc_kernel,
        grid=(mi // bm, nj // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bk, r), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((bm, r), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mi, r), x.dtype),
        interpret=True,
    )(x, ht)


# --------------------------------------------------------------------------
# wtx: Xᵀ·W  -> (nj × r), accumulating over the mi dimension
# --------------------------------------------------------------------------

def _matmul_at_kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...].T, w_ref[...], preferred_element_type=o_ref.dtype)


def wtx(x, w):
    mi, nj = x.shape
    _, r = w.shape
    bn = _tile(nj, TILE_ROWS)
    bk = _tile(mi, TILE_K)
    return pl.pallas_call(
        _matmul_at_kernel,
        grid=(nj // bn, mi // bk),
        in_specs=[
            pl.BlockSpec((bk, bn), lambda j, k: (k, j)),
            pl.BlockSpec((bk, r), lambda j, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((bn, r), lambda j, k: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((nj, r), x.dtype),
        interpret=True,
    )(x, w)


# --------------------------------------------------------------------------
# Fused BCD projected-gradient step
# --------------------------------------------------------------------------

def _bcd_kernel(fm_ref, g_ref, p_ref, lip_ref, o_ref):
    fm = fm_ref[...]
    grad = jnp.dot(fm, g_ref[...], preferred_element_type=fm.dtype) - p_ref[...]
    o_ref[...] = jnp.maximum(0.0, fm - grad / lip_ref[0, 0])


def bcd_update(fm, g, p, lip):
    rows, r = fm.shape
    bm = _tile(rows, TILE_ROWS)
    return pl.pallas_call(
        _bcd_kernel,
        grid=(rows // bm,),
        in_specs=[
            pl.BlockSpec((bm, r), lambda i: (i, 0)),
            pl.BlockSpec((r, r), lambda i: (0, 0)),
            pl.BlockSpec((bm, r), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, r), fm.dtype),
        interpret=True,
    )(fm, g, p, lip)


# --------------------------------------------------------------------------
# Fused MU step
# --------------------------------------------------------------------------

def _mu_kernel(f_ref, g_ref, p_ref, o_ref):
    f = f_ref[...]
    den = jnp.dot(f, g_ref[...], preferred_element_type=f.dtype) + MU_EPS
    o_ref[...] = f * p_ref[...] / den


def mu_update(f, g, p):
    rows, r = f.shape
    bm = _tile(rows, TILE_ROWS)
    return pl.pallas_call(
        _mu_kernel,
        grid=(rows // bm,),
        in_specs=[
            pl.BlockSpec((bm, r), lambda i: (i, 0)),
            pl.BlockSpec((r, r), lambda i: (0, 0)),
            pl.BlockSpec((bm, r), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, r), f.dtype),
        interpret=True,
    )(f, g, p)
