"""AOT lowering: JAX/Pallas graphs → HLO text artifacts + manifest.

Runs ONCE at build time (`make artifacts`); the Rust runtime loads the HLO
text through `HloModuleProto::from_text_file` and compiles it on the PJRT
CPU client. HLO **text** (not serialized proto) is the interchange format:
jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects,
while the text parser reassigns ids (see /opt/xla-example/README.md).

Shape sets are derived from experiment presets by mirroring the TT driver's
stage arithmetic (Alg 2): for fixed dims/grid/ranks every local-op shape a
rank will request is known in advance. Shapes that don't divide evenly on
the grid are skipped — the Rust PJRT backend falls back to the native
backend for any shape missing from the manifest.

Usage:
    python -m compile.aot --out ../artifacts [--preset default]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def to_hlo_text(fn, *args) -> str:
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


# --------------------------------------------------------------------------
# Op catalog: key -> (fn, arg specs)
# --------------------------------------------------------------------------

def op_entry(op: str, *dims):
    """Build (key, fn, arg_specs) for an op instance."""
    if op == "gram":
        rows, r = dims
        return f"gram_{rows}x{r}", model.gram, [spec(rows, r)]
    if op == "xht":
        mi, nj, r = dims
        return f"xht_{mi}x{nj}x{r}", model.xht, [spec(mi, nj), spec(nj, r)]
    if op == "wtx":
        mi, nj, r = dims
        return f"wtx_{mi}x{nj}x{r}", model.wtx, [spec(mi, nj), spec(mi, r)]
    if op == "bcd":
        rows, r = dims
        return (
            f"bcd_{rows}x{r}",
            model.bcd_update,
            [spec(rows, r), spec(r, r), spec(rows, r), spec(1, 1)],
        )
    if op == "mu":
        rows, r = dims
        return (
            f"mu_{rows}x{r}",
            model.mu_update,
            [spec(rows, r), spec(r, r), spec(rows, r)],
        )
    if op == "nmf_iter_bcd":
        m, n, r = dims
        return (
            f"nmf_iter_bcd_{m}x{n}x{r}",
            model.nmf_iter_bcd,
            [spec(m, n), spec(m, r), spec(n, r)],
        )
    raise ValueError(f"unknown op {op}")


def stage_shapes(dims, ranks, pr, pc):
    """Mirror Alg 2's stage arithmetic: yield every local-op shape the
    distributed driver requests for fixed dims/grid/ranks."""
    out = []
    d = len(dims)
    r_prev = 1
    s_rest = 1
    for n in dims:
        s_rest *= n
    for l in range(d - 1):
        n_l = dims[l]
        m = r_prev * n_l
        ncols = s_rest // n_l
        r = ranks[l]
        if m % pr == 0 and ncols % pc == 0:
            mi, nj = m // pr, ncols // pc
            if mi % pc == 0 and nj % pr == 0:
                mw, nh = mi // pc, nj // pr
                out.append(("xht", (mi, nj, r)))
                out.append(("wtx", (mi, nj, r)))
                for rows in {mw, nh}:
                    out.append(("gram", (rows, r)))
                    out.append(("bcd", (rows, r)))
                    out.append(("mu", (rows, r)))
                if pr == 1 and pc == 1:
                    out.append(("nmf_iter_bcd", (m, ncols, r)))
        r_prev = r
        s_rest = ncols
    return out


def preset_ops(name: str):
    """Named shape presets. 'default' covers the quickstart + integration
    tests; 'bench' adds the figure-bench shapes."""
    ops = []
    if name in ("default", "bench"):
        # Tiny shapes exercised by Rust integration tests.
        ops += [
            ("gram", (6, 2)),
            ("xht", (4, 6, 2)),
            ("wtx", (4, 6, 2)),
            ("bcd", (6, 2)),
            ("mu", (6, 2)),
            ("nmf_iter_bcd", (8, 12, 2)),
        ]
        # Quickstart: 16^4 tensor, ranks 4, serial + 2x2 grid.
        ops += stage_shapes([16] * 4, [4, 4, 4], 1, 1)
        ops += stage_shapes([16] * 4, [4, 4, 4], 2, 2)
    if name == "bench":
        # Figure-bench workload (scaled 64^4 strong-scaling stage shapes).
        for k in range(1, 4):
            pr, pc = 2**k, 8 // (2 ** min(k, 3)) or 1
            ops += stage_shapes([64] * 4, [10, 10, 10], pr, max(pc, 1))
        ops += stage_shapes([64] * 4, [10, 10, 10], 1, 1)
    # Dedup by key.
    seen = {}
    for op, dims in ops:
        key = (op, dims)
        seen[key] = True
    return list(seen)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--preset", default="default", choices=["default", "bench"])
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"dtype": "f32", "ops": []}
    entries = preset_ops(args.preset)
    print(f"lowering {len(entries)} op instances (preset={args.preset})")
    for op, dims in entries:
        key, fn, specs = op_entry(op, *dims)
        path = os.path.join(args.out, f"{key}.hlo.txt")
        text = to_hlo_text(fn, *specs)
        with open(path, "w") as f:
            f.write(text)
        manifest["ops"].append(
            {
                "key": key,
                "op": op,
                "dims": list(dims),
                "file": f"{key}.hlo.txt",
                "outputs": 4 if op == "nmf_iter_bcd" else 1,
            }
        )
        print(f"  {key:<28} -> {len(text)} chars")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['ops'])} ops -> {args.out}/manifest.json")


if __name__ == "__main__":
    main()
