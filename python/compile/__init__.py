"""Build-time compile path: L2 JAX graphs + L1 Pallas kernels + AOT lowering.
Never imported at runtime — the Rust binary loads the HLO artifacts directly."""
